import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "/root/repo/src")
import dataclasses, json, time
import repro.configs as C
import repro.launch.dryrun as DR

# monkeypatch get_config to apply overrides per probe
import repro.launch.specs  # noqa

PROBES = [
    # (arch, shape, overrides-dict, tag)
    ("gemma3_12b", "train_4k", {"remat_mode": "pattern", "flash_remat": False}, "A0-pattern-noflashremat"),
    ("gemma3_12b", "train_4k", {"remat_mode": "pattern", "flash_remat": True}, "A1-pattern-flashremat"),
    ("gemma3_12b", "train_4k", {"remat_mode": "block", "flash_remat": True}, "A2-block-flashremat"),
    ("gemma3_12b", "train_4k", {"remat_mode": "double", "flash_remat": True}, "A3-double-flashremat"),
    ("qwen2p5_32b", "train_4k", {"remat_mode": "pattern", "flash_remat": False}, "B0-pattern"),
    ("qwen2p5_32b", "train_4k", {"remat_mode": "block", "flash_remat": True}, "B1-block-flashremat"),
    ("deepseek_v2_236b", "prefill_32k", {"remat_mode": "pattern", "flash_remat": False}, "C0-baseline"),
    ("deepseek_v2_236b", "prefill_32k", {"remat_mode": "block", "flash_remat": True}, "C1-block-flashremat"),
    ("arctic_480b", "train_4k", {"remat_mode": "pattern", "flash_remat": False}, "D0-baseline"),
    ("arctic_480b", "train_4k", {"remat_mode": "block", "flash_remat": True}, "D1-block-flashremat"),
]

orig_get = C.get_config
out = {}
for arch, shape, over, tag in PROBES:
    def patched(a, _arch=arch, _over=over):
        cfg = orig_get(a)
        return dataclasses.replace(cfg, **_over)
    DR.get_config = patched
    try:
        t0 = time.time()
        d, _ = DR.lower_cell(arch, shape, False)
        d["probe"] = tag
        out[f"{arch}__{shape}__{tag}"] = d
        print(f"PROBE {tag}: step={d['step_time_s']*1e3:.0f}ms "
              f"comp={d['compute_s']:.2f}s mem={d['memory_s']:.2f}s coll={d['collective_s']:.2f}s "
              f"temp={(d.get('temp_bytes_per_chip') or 0)/1e9:.1f}GB frac={d['roofline_fraction']:.3f} "
              f"({time.time()-t0:.0f}s)", flush=True)
    except Exception as e:
        print(f"PROBE {tag} FAILED: {type(e).__name__} {str(e)[:200]}", flush=True)
with open("/root/repo/experiments/hillclimb_probes.json", "w") as f:
    json.dump(out, f, indent=1)
print("DONE")
