import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "/root/repo/src")
import dataclasses, json, time
import repro.configs as C
import repro.launch.dryrun as DR

PROBES = [
    # cell C: deepseek prefill -- flash chunk geometry (memory-bound: 1489s)
    ("deepseek_v2_236b", "prefill_32k", {"k_chunk": 2048}, "C2-kc2048"),
    ("deepseek_v2_236b", "prefill_32k", {"k_chunk": 4096, "q_chunk": 1024}, "C3-kc4096-qc1024"),
    ("deepseek_v2_236b", "prefill_32k", {"k_chunk": 8192, "q_chunk": 2048}, "C4-kc8192-qc2048"),
    # cell A: gemma3 train -- collective-bound; bigger chunks cut recomputed
    # per-chunk collectives too
    ("gemma3_12b", "train_4k", {"remat_mode": "pattern", "flash_remat": True,
                                "k_chunk": 4096, "q_chunk": 2048}, "A4-bigchunks"),
    ("qwen2p5_32b", "train_4k", {"remat_mode": "pattern", "flash_remat": True,
                                 "k_chunk": 4096, "q_chunk": 2048}, "B2-bigchunks"),
    # cell D: arctic -- combine winners
    ("arctic_480b", "train_4k", {"remat_mode": "block", "flash_remat": True,
                                 "k_chunk": 4096, "q_chunk": 2048}, "D2-block-bigchunks"),
]

orig_get = C.get_config
out = {}
if os.path.exists("/root/repo/experiments/hillclimb_probes.json"):
    out = json.load(open("/root/repo/experiments/hillclimb_probes.json"))
for arch, shape, over, tag in PROBES:
    def patched(a, _over=over):
        return dataclasses.replace(orig_get(a), **_over)
    DR.get_config = patched
    try:
        t0 = time.time()
        d, _ = DR.lower_cell(arch, shape, False)
        d["probe"] = tag
        out[f"{arch}__{shape}__{tag}"] = d
        print(f"PROBE {tag}: step={d['step_time_s']*1e3:.0f}ms "
              f"comp={d['compute_s']:.2f}s mem={d['memory_s']:.2f}s coll={d['collective_s']:.2f}s "
              f"temp={(d.get('temp_bytes_per_chip') or 0)/1e9:.1f}GB frac={d['roofline_fraction']:.3f} "
              f"({time.time()-t0:.0f}s)", flush=True)
    except Exception as e:
        print(f"PROBE {tag} FAILED: {type(e).__name__} {str(e)[:200]}", flush=True)
with open("/root/repo/experiments/hillclimb_probes.json", "w") as f:
    json.dump(out, f, indent=1)
print("DONE2")
