"""Result-set batching (paper Sec. 3.2.2)."""
import numpy as np

from repro.core.batching import (
    batch_ranges, compute_num_batches, estimate_result_size,
)
from repro.core.grid import build_grid, build_tile_plan
from repro.core import SelfJoinConfig, self_join
from repro.data import exponential_dataset
from repro.kernels import ops


def test_min_three_batches():
    # the paper always pipelines with >= 3 streams/batches
    assert compute_num_batches(10, batch_size=10**8) == 3
    assert compute_num_batches(0, batch_size=10**8) == 3


def test_batch_count_scales_with_result_size():
    assert compute_num_batches(10**9, batch_size=10**8) == 10
    assert compute_num_batches(3 * 10**8 + 1, batch_size=10**8) == 4


def test_batch_ranges_cover_disjointly():
    ranges = list(batch_ranges(1000, 7))
    assert ranges[0][0] == 0 and ranges[-1][1] == 1000
    for (a0, a1), (b0, b1) in zip(ranges, ranges[1:]):
        assert a1 == b0 and a0 < a1


def test_estimate_within_factor_of_truth():
    d = exponential_dataset(1500, 16, seed=30)
    eps = 0.06
    grid = build_grid(d, eps, 4)
    plan = build_tile_plan(grid, 16, sortidu=True)
    tiles, tlen = ops.make_tiles(
        grid.pts_sorted, plan.tile_start, plan.tile_len, 16, 8
    )
    est = estimate_result_size(
        tiles, tlen, plan, eps=eps, dim_block=8, backend="jnp",
        sample_frac=0.2,
    )
    truth = self_join(d, SelfJoinConfig(eps=eps, k=4, tile_size=16,
                                        dim_block=8)).stats.num_results
    assert truth / 3 <= est <= truth * 3  # sampling estimate, same magnitude


def test_pairs_mode_uses_batches_and_matches():
    # end-to-end through the batched pairs path with a small batch size
    d = exponential_dataset(400, 16, seed=31)
    cfg = SelfJoinConfig(eps=0.08, k=4, tile_size=16, dim_block=8,
                         batch_size=50, min_batches=3)
    res = self_join(d, cfg, return_pairs=True)
    ref = self_join(d, SelfJoinConfig(eps=0.08, k=4, tile_size=16, dim_block=8))
    assert res.stats.num_results == ref.stats.num_results
    np.testing.assert_array_equal(res.counts, ref.counts)
