"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finite values (the assignment's smoke requirement),
plus decode-vs-train logit consistency (cache/step math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import (
    count_params_analytic, decode_step, forward_train, init_params, prefill,
)
from repro.train import OptHParams, adamw_init, make_train_step


def _batch(cfg, b=2, s=24, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    }
    batch["labels"] = batch["tokens"]
    if cfg.encoder_groups is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, 16, cfg.enc_input_dim)), jnp.float32
        )
    if cfg.vision_tokens:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.vision_dim)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    loss, logits = forward_train(params, batch, cfg)
    assert logits.shape == (2, 24, cfg.vocab)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = make_train_step(cfg, OptHParams(warmup_steps=1, total_steps=10))
    opt = adamw_init(params, cfg.opt_state_dtype)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt2["step"]) == 1
    # params actually moved
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_consistent_with_train_forward(arch):
    cfg = get_reduced_config(arch)
    overrides = dict(activation_dtype="float32")
    if cfg.moe is not None:  # avoid capacity drops (they legitimately differ)
        overrides["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, **overrides)
    params = init_params(cfg, jax.random.key(1))
    b, s = 2, 24
    batch = _batch(cfg, b, s, seed=3)
    _, logits = forward_train(params, batch, cfg)
    ctx = dict(batch)
    ctx["tokens"] = batch["tokens"][:, : s - 1]
    ctx["labels"] = ctx["tokens"]
    _, caches, memory = prefill(params, ctx, cfg, cache_len=32)
    lg, _ = decode_step(
        params, caches, batch["tokens"][:, s - 1], jnp.int32(s - 1), cfg,
        memory=memory,
    )
    ref = logits[:, s - 1]
    rel = float(jnp.max(jnp.abs(lg - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 5e-3, f"{arch}: decode/train mismatch rel={rel}"


def test_published_param_counts_in_range():
    """Analytic parameter counts must land near the published sizes."""
    expected = {
        "gemma3_12b": (10e9, 14e9),
        "phi3_mini_3p8b": (3.5e9, 4.2e9),
        "qwen3_32b": (30e9, 36e9),
        "qwen2p5_32b": (30e9, 36e9),
        "recurrentgemma_2b": (2.2e9, 3.3e9),
        "arctic_480b": (430e9, 520e9),
        "deepseek_v2_236b": (210e9, 260e9),
        "seamless_m4t_medium": (0.45e9, 1.4e9),
        "llama3p2_vision_11b": (9e9, 12e9),
        "xlstm_125m": (0.1e9, 0.17e9),
    }
    for arch, (lo, hi) in expected.items():
        n = count_params_analytic(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_much_smaller():
    cfg = get_config("arctic_480b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()
