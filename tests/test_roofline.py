"""HLO parser: exact dot FLOPs, while trip counts, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import parse_hlo_module
from repro.roofline.analysis import roofline_terms, V5E


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    costs = parse_hlo_module(c.as_text())
    assert costs.dot_flops == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        def body(c, wi):
            return jnp.einsum("bd,de->be", c, wi,
                              preferred_element_type=jnp.float32), None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    c = _compile(f, x, w)
    costs = parse_hlo_module(c.as_text())
    assert costs.num_while_loops >= 1
    assert costs.dot_flops == pytest.approx(12 * 2 * 16 * 64 * 64, rel=0.01)


def test_nested_scan_trip_counts_multiply():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.dot(ci, wi, preferred_element_type=jnp.float32), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, w)
        return y
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    c = _compile(f, x, w)
    costs = parse_hlo_module(c.as_text())
    assert costs.dot_flops == pytest.approx(5 * 3 * 2 * 8 * 32 * 32, rel=0.01)


def test_in_place_cache_update_charges_slice_not_buffer():
    def f(cache, upd):
        return jax.lax.dynamic_update_slice(cache, upd, (0, 0))
    cache = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    # donate the buffer so XLA aliases in place (otherwise it inserts a
    # defensive full copy, which the traffic model correctly charges)
    c = jax.jit(f, donate_argnums=(0,)).lower(cache, upd).compile()
    costs = parse_hlo_module(c.as_text())
    # full buffer is 4 MB; the update slice is 1 KB -> traffic must be << buffer
    assert costs.hbm_bytes < 4096 * 256 * 4 / 4


def test_roofline_report_terms():
    rep = roofline_terms(
        arch="x", shape="train_4k", mesh_desc="m", chips=256,
        hlo_text="", model_flops=1e15,
    )
    assert rep.compute_s == 0.0 and rep.dominant == "compute"
    rep2 = roofline_terms(
        arch="x", shape="s", mesh_desc="m", chips=2,
        hlo_text="""
HloModule t, entry_computation_layout={()->f32[]}

ENTRY %main () -> f32[] {
  %p = f32[1024,1024]{1,0} parameter(0)
  %q = f32[1024,1024]{1,0} parameter(1)
  %dot = f32[1024,1024]{1,0} dot(%p, %q), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-reduce = f32[1024,1024]{1,0} all-reduce(%dot), replica_groups=[1,2]<=[2], to_apply=%add
}
""",
        model_flops=2.0 * 1024**3,
    )
    assert rep2.flops_per_chip == 2 * 1024**3
    # AR wire: 2 * 4MB * (2-1)/2 = 4 MB
    assert rep2.wire_bytes_per_chip == pytest.approx(4 * 1024**2, rel=0.01)
    assert 0 < rep2.roofline_fraction <= 1.0


def test_collective_group_size_parsing():
    hlo = """
HloModule t

ENTRY %main () -> f32[] {
  %p = f32[256,256]{1,0} parameter(0)
  %ag = f32[256,4096]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={1}
}
"""
    costs = parse_hlo_module(hlo)
    # AG wire: result 4 MB * 15/16
    assert costs.collective_wire_bytes == pytest.approx(
        256 * 4096 * 4 * 15 / 16, rel=0.01
    )
