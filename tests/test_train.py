"""Training substrate: optimizer math, loss descent, checkpoint/restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import forward_train, init_params
from repro.train import (
    OptHParams, adamw_init, adamw_update, make_train_step,
    restore_checkpoint, save_checkpoint, latest_step,
)
from repro.train.optimizer import global_norm, schedule


def test_adamw_matches_manual_reference():
    hp = OptHParams(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    clip_norm=1e9, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st = adamw_init(p)
    p1, st1, _ = adamw_update(p, g, st, hp)
    m = 0.1 * np.array([0.5, 0.25])
    v = 0.01 * np.array([0.25, 0.0625])
    upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    lr1 = float(schedule(hp, jnp.int32(1)))
    np.testing.assert_allclose(np.asarray(p1["w"]), np.array([1.0, -2.0]) - lr1 * upd, rtol=1e-5)


def test_grad_clipping_bounds_update():
    hp = OptHParams(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    st = adamw_init(p)
    _, st1, metrics = adamw_update(p, g, st, hp)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    # clipped first moment: g * (1/200) * 0.1
    np.testing.assert_allclose(np.asarray(st1["m"]["w"]), 0.05, rtol=1e-5)


def test_loss_decreases_on_tiny_model():
    cfg = get_reduced_config("phi3_mini_3p8b")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 64, (4, 16)), jnp.int32)}
    batch["labels"] = batch["tokens"]
    hp = OptHParams(lr=5e-3, warmup_steps=0, total_steps=10**6, weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, hp))
    opt = adamw_init(params)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = get_reduced_config("xlstm_125m")
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    tree = {"params": params, "opt": opt}
    d = save_checkpoint(str(tmp_path), 7, tree, extra={"data_cursor": 12345})
    assert os.path.exists(os.path.join(d, "manifest.json"))
    assert latest_step(str(tmp_path)) == 7

    like = jax.eval_shape(lambda: tree)
    restored, step, extra = restore_checkpoint(str(tmp_path), like)
    assert step == 7 and extra["data_cursor"] == 12345
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_keeps_previous_on_partial_write(tmp_path):
    cfg = get_reduced_config("xlstm_125m")
    params = init_params(cfg, jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, {"params": params})
    # simulate an interrupted save: stray tmp dir must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    params = {"w": jnp.zeros((4, 4))}
    save_checkpoint(str(tmp_path), 1, params)
    bad = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
