"""Streaming (vocab-chunked) cross-entropy == dense CE, bit-for-bit paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import forward_loss, forward_train, init_params
from repro.models.layers import blocked_cross_entropy


@pytest.mark.parametrize("arch", ["gemma3_12b", "phi3_mini_3p8b", "arctic_480b"])
@pytest.mark.parametrize("chunk", [100, 512, 8192])  # overlap / exact / single
def test_blocked_ce_matches_dense(arch, chunk):
    cfg = dataclasses.replace(
        get_reduced_config(arch), activation_dtype="float32", ce_chunk=chunk
    )
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
    lab = np.asarray(rng.integers(0, cfg.vocab, (2, 16)), np.int32)
    lab[0, :3] = -1
    batch["labels"] = jnp.asarray(lab)
    l_dense, _ = forward_train(params, batch, cfg)
    l_blocked = forward_loss(params, batch, cfg)
    assert float(l_dense) == pytest.approx(float(l_blocked), rel=1e-6)


def test_blocked_ce_grad_matches_dense_grad():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 50)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 50, (2, 8)), jnp.int32)

    def dense(args):
        xx, ww = args
        logits = jnp.einsum("bsd,dv->bsv", xx, ww)
        lp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(lp, labels[..., None], -1)[..., 0]
        return -ll.mean()

    def blocked(args):
        xx, ww = args
        return blocked_cross_entropy(xx, labels, w=ww, chunk=13)

    g1 = jax.grad(dense)((x, w))
    g2 = jax.grad(blocked)((x, w))
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-6)


def test_blocked_ce_all_masked_is_finite():
    x = jnp.ones((1, 4, 8))
    w = jnp.ones((8, 20))
    labels = jnp.full((1, 4), -1, jnp.int32)
    loss = blocked_cross_entropy(x, labels, w=w, chunk=7)
    assert float(loss) == 0.0
