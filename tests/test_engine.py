"""Device-resident SelfJoinEngine vs the brute-force and host-loop oracles."""
import dataclasses

import numpy as np
import pytest

from oracles import brute_counts, brute_pairs, pair_set as _pair_set
from repro.core import (
    EngineConfig,
    SelfJoinConfig,
    SelfJoinEngine,
    self_join,
    self_join_hostloop,
)
from repro.core import batching as batching_mod
from repro.data import exponential_dataset, uniform_dataset


def test_engine_counts_and_pairs_match_brute(dataset_case):
    name, d, eps = dataset_case
    cfg = SelfJoinConfig(eps=eps, k=4, tile_size=16, dim_block=8)
    eng = SelfJoinEngine(d, cfg)
    res_c = eng.count()
    res_p = eng.pairs()
    np.testing.assert_array_equal(res_c.counts, brute_counts(d, eps))
    np.testing.assert_array_equal(res_p.counts, res_c.counts)
    assert _pair_set(res_p.pairs) == _pair_set(brute_pairs(d, eps))
    assert len(res_p.pairs) == res_p.stats.num_results
    assert res_c.stats.num_chunks >= 1


def test_engine_matches_hostloop_exactly():
    d = exponential_dataset(400, 16, seed=24)
    cfg = SelfJoinConfig(eps=0.07, k=4, tile_size=16, dim_block=8)
    old = self_join_hostloop(d, cfg, return_pairs=True)
    new = SelfJoinEngine(d, cfg).pairs()
    np.testing.assert_array_equal(new.counts, old.counts)
    assert _pair_set(new.pairs) == _pair_set(old.pairs)
    assert new.stats.num_candidates == old.stats.num_candidates


def test_engine_eps_zero_duplicates():
    # eps=0 degenerates to duplicate detection: counts = multiplicity
    # (1/64-quantized so the fp32 matmul form gives exact zero distances)
    rng = np.random.default_rng(25)
    base = (np.round(rng.random((60, 6)) * 64) / 64).astype(np.float32)
    d = np.concatenate([base, base[:20], base[:5]])  # dup groups of 2 and 3
    cfg = SelfJoinConfig(eps=0.0, k=3, tile_size=8, dim_block=8)
    eng = SelfJoinEngine(d, cfg)
    res = eng.pairs()
    np.testing.assert_array_equal(res.counts, brute_counts(d, 0.0))
    assert _pair_set(res.pairs) == _pair_set(brute_pairs(d, 0.0))
    # also through the public wrapper
    np.testing.assert_array_equal(self_join(d, cfg).counts, res.counts)


def test_engine_duplicate_points_eps_positive():
    d = np.tile(np.random.default_rng(26).random((30, 5)).astype(np.float32), (3, 1))
    cfg = SelfJoinConfig(eps=0.1, k=3, tile_size=8, dim_block=8)
    res = SelfJoinEngine(d, cfg).pairs()
    np.testing.assert_array_equal(res.counts, brute_counts(d, 0.1))
    assert _pair_set(res.pairs) == _pair_set(brute_pairs(d, 0.1))


def test_engine_dims_smaller_than_dim_block():
    d = uniform_dataset(300, 3, seed=27)  # n=3 pads to dim_block=32
    cfg = SelfJoinConfig(eps=0.2, k=2)    # default tile_size/dim_block
    res = SelfJoinEngine(d, cfg).pairs()
    np.testing.assert_array_equal(res.counts, brute_counts(d, 0.2))
    assert _pair_set(res.pairs) == _pair_set(brute_pairs(d, 0.2))


def test_engine_empty_and_tiny_inputs():
    cfg = SelfJoinConfig(eps=0.1, k=2)
    empty = np.zeros((0, 8), np.float32)
    eng = SelfJoinEngine(empty, cfg)
    assert eng.count().counts.shape == (0,)
    assert eng.pairs().pairs.shape == (0, 2)
    one = np.random.default_rng(0).random((1, 8)).astype(np.float32)
    res = SelfJoinEngine(one, cfg).pairs()
    assert res.counts.tolist() == [1]
    assert _pair_set(res.pairs) == {(0, 0)}


def test_engine_pairs_overflow_raises_cleanly():
    d = exponential_dataset(300, 8, seed=28)
    cfg = SelfJoinConfig(eps=0.2, k=3, tile_size=16, dim_block=8)
    eng = SelfJoinEngine(d, cfg)
    total = eng.count().stats.num_results
    assert total > 10
    with pytest.raises(RuntimeError, match="max_pairs"):
        eng.pairs(max_pairs=total - 1)
    # the engine stays usable after an overflow
    res = eng.pairs(max_pairs=total)
    assert len(res.pairs) == total


def test_engine_auto_grow_recovers_from_bad_estimate(monkeypatch):
    d = (np.round(uniform_dataset(400, 4, seed=29) * 64) / 64).astype(np.float32)
    eps = 0.5  # dense: far more than the 4096-row floor
    monkeypatch.setattr(
        batching_mod, "estimate_result_size", lambda *a, **k: 1
    )
    cfg = SelfJoinConfig(eps=eps, k=2, tile_size=16, dim_block=8)
    res = SelfJoinEngine(d, cfg).pairs()
    assert res.stats.overflow_retries > 0
    np.testing.assert_array_equal(res.counts, brute_counts(d, eps))
    assert len(res.pairs) == res.stats.num_results > 4096


def test_engine_reuse_across_eps_matches_fresh_runs():
    d = exponential_dataset(350, 16, seed=30)
    eps_values = [0.04, 0.08, 0.12]
    cfg = SelfJoinConfig(eps=max(eps_values), k=4, tile_size=16, dim_block=8)
    eng = SelfJoinEngine(d, cfg)
    swept = eng.query(eps_values, return_pairs=True)
    for eps, res in zip(eps_values, swept):
        fresh = self_join(
            d, dataclasses.replace(cfg, eps=eps), return_pairs=True
        )
        np.testing.assert_array_equal(res.counts, fresh.counts)
        assert _pair_set(res.pairs) == _pair_set(fresh.pairs)
    # sweeping upward transparently rebuilds the index
    bigger = eng.count(0.2)
    np.testing.assert_array_equal(bigger.counts, brute_counts(d, 0.2))


def test_engine_pallas_backend_parity():
    d = exponential_dataset(250, 16, seed=31)
    base = SelfJoinConfig(eps=0.08, k=4, tile_size=16, dim_block=8)
    r_jnp = SelfJoinEngine(d, base).pairs()
    r_pl = SelfJoinEngine(
        d, dataclasses.replace(base, use_pallas=True)
    ).pairs()
    np.testing.assert_array_equal(r_jnp.counts, r_pl.counts)
    assert _pair_set(r_jnp.pairs) == _pair_set(r_pl.pairs)


def test_engine_count_shortc_stats_match_hostloop():
    d = exponential_dataset(400, 64, seed=32)
    cfg = SelfJoinConfig(eps=0.1, k=6, tile_size=16, dim_block=8)
    old = self_join_hostloop(d, cfg)
    new = SelfJoinEngine(d, cfg).count()
    np.testing.assert_array_equal(new.counts, old.counts)
    assert new.stats.dim_blocks_skipped == old.stats.dim_blocks_skipped
    assert new.stats.dim_blocks_total == old.stats.dim_blocks_total


def test_engine_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(count_chunk=0)
    with pytest.raises(ValueError):
        EngineConfig(max_pairs=-1)
    with pytest.raises(ValueError):
        SelfJoinConfig(eps=-0.1)
