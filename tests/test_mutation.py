"""The mutable index (DESIGN.md #10): delta inserts, tombstones, compaction.

Correctness is differential against ``oracles.ChurnOracle`` (the brute-force
mirror of the global-id contract) over every dataset kind in the shared
matrix, both BEFORE and AFTER compaction; the refactor's operational
contracts are pinned as hard counters:

  * swap atomicity -- a ``compact()`` between requests changes NO answer bit
    and, because executables are keyed by shape bucket rather than data
    identity, adds ZERO traces (``ServiceStats.num_traces``);
  * tombstone edges -- delete-everything, delete-then-reinsert identical
    coordinates (new global id, same geometry), eps == 0 duplicate joins;
  * save/load round-trips the full churn state (delta + tombstones +
    id log), not just the snapshot;
  * an interleaved insert/delete/compact/query stream (hypothesis-driven)
    matches the oracle at every step.
"""
import numpy as np
import pytest

try:  # hypothesis is a dev-only dependency (see test_properties.py); the
    # interleaved-stream property skips without it, but the deterministic
    # stream test below keeps churn-sequence coverage either way
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from oracles import ChurnOracle, make_dataset, pair_set
from repro.core import SelfJoinConfig
from repro.join import QueryService, SimilarityIndex


def _cfg(eps, **kw):
    kw.setdefault("k", 4)
    kw.setdefault("tile_size", 16)
    kw.setdefault("dim_block", 8)
    return SelfJoinConfig(eps=eps, **kw)


def _queries(d, seed, n_extra=16):
    """Mixed batch: dataset rows (exact hits, duplicates) + fresh points."""
    extra = make_dataset("uniform", n_extra, d.shape[1], seed=seed)
    return np.concatenate([d[: min(25, len(d))], extra])


def _assert_matches_oracle(svc, oracle, q, eps, k=3):
    """range_count + range_pairs + kNN all equal the churn oracle, bitwise."""
    rc = svc.range_count(q, eps)
    np.testing.assert_array_equal(rc.counts, oracle.range_count(q, eps))
    rp = svc.range_pairs(q, eps)
    np.testing.assert_array_equal(rp.pairs, oracle.range_pairs(q, eps))
    np.testing.assert_array_equal(rp.counts, rc.counts)
    kn = svc.knn(q, k)
    want_idx, want_dist = oracle.topk(q, k)
    np.testing.assert_array_equal(kn.indices, want_idx)
    np.testing.assert_array_equal(kn.distances, want_dist)
    return rc, rp, kn


# -- differential matrix: every dataset kind, pre- and post-compact ----------


def test_mutated_index_matches_churn_oracle(dataset_case):
    name, d, eps = dataset_case
    seed_pts, fresh = d[:-30], d[-30:]
    idx = SimilarityIndex(seed_pts, _cfg(eps))
    svc = QueryService(idx)
    oracle = ChurnOracle(seed_pts)
    q = _queries(d, seed=81)

    # inserts: points near the data (the held-out rows) plus exact
    # duplicates of indexed rows (multiplicity under churn)
    ins = np.concatenate([fresh, seed_pts[:5]])
    np.testing.assert_array_equal(idx.insert(ins), oracle.insert(ins))
    # deletes: a mix of seed ids (tombstones) and freshly inserted ids
    # (delta-side removal)
    dead = np.array(
        [0, 3, len(seed_pts) // 2, len(seed_pts) + 2, len(seed_pts) + 31],
        np.int64,
    )
    assert idx.delete(dead) == oracle.delete(dead) == len(dead)

    assert idx.num_points == oracle.live_count
    rc, _, _ = _assert_matches_oracle(svc, oracle, q, eps)
    assert rc.stats.delta_size == idx.delta_size > 0
    assert rc.stats.tombstone_count == idx.tombstone_count > 0
    assert rc.stats.epoch == 0

    # a smaller radius reuses the same snapshot; a larger one serves from a
    # TEMPORARY rebuild, the resident build radius never moves
    _assert_matches_oracle(svc, oracle, q, eps / 2)
    over = svc.range_count(q, eps * 2)
    np.testing.assert_array_equal(over.counts, oracle.range_count(q, eps * 2))
    assert over.stats.index_rebuilds == 1
    assert idx.index_eps == eps

    # compaction folds the churn into a fresh snapshot: same answers, ids
    # stable, churn buffers empty
    idx.compact()
    assert idx.epoch == 1
    assert idx.delta_size == 0 and idx.tombstone_count == 0
    assert idx.num_points == oracle.live_count
    rc2, _, _ = _assert_matches_oracle(svc, oracle, q, eps)
    assert rc2.stats.epoch == 1

    # churn on top of the compacted index still matches
    more = oracle.insert(fresh[:7])
    np.testing.assert_array_equal(idx.insert(fresh[:7]), more)
    idx.delete(more[:2])
    oracle.delete(more[:2])
    _assert_matches_oracle(svc, oracle, q, eps)


# -- swap atomicity: bit-identical answers, zero traces ----------------------


def test_compact_swap_is_atomic_zero_traces_and_bit_identical():
    """The tentpole contract: executables are keyed by shape bucket, never
    by data identity, so swapping in a compacted same-bucket snapshot
    retraces NOTHING and changes NO answer bit -- counts, pairs (global
    ids), and kNN all serve identically before, during, and after."""
    d = make_dataset("exponential", 300, 8, seed=83)
    idx = SimilarityIndex(d[:280], _cfg(0.3))
    svc = QueryService(idx)
    oracle = ChurnOracle(d[:280])
    q = _queries(d, seed=84)

    # warm every executable: clean stream, then churn stream (aux passes)
    _assert_matches_oracle(svc, oracle, q, 0.3, k=1)
    idx.insert(d[280:])
    oracle.insert(d[280:])
    idx.delete(np.arange(0, 40, 3))
    oracle.delete(np.arange(0, 40, 3))
    before = _assert_matches_oracle(svc, oracle, q, 0.3, k=1)

    traces0 = svc.total.num_traces
    pending = idx.prepare_compact()  # build happens off the serving path
    mid = _assert_matches_oracle(svc, oracle, q, 0.3, k=1)  # still epoch 0
    assert mid[0].stats.epoch == 0
    idx.apply_compact(pending)      # the atomic swap
    after = _assert_matches_oracle(svc, oracle, q, 0.3, k=1)
    assert after[0].stats.epoch == 1
    assert after[0].stats.delta_size == 0
    assert after[0].stats.tombstone_count == 0

    for b, m, a in zip(before, mid, after):
        for field in ("counts", "pairs", "indices", "distances"):
            if hasattr(b, field):
                np.testing.assert_array_equal(
                    getattr(b, field), getattr(m, field)
                )
                np.testing.assert_array_equal(
                    getattr(b, field), getattr(a, field)
                )
    assert svc.total.num_traces == traces0  # the swap retraced NOTHING


def test_apply_compact_rejects_stale_pending():
    d = make_dataset("uniform", 60, 6, seed=85)
    idx = SimilarityIndex(d, _cfg(0.2))
    pending = idx.prepare_compact()
    idx.insert(d[:3])  # churn lands after the rebuild started
    with pytest.raises(RuntimeError):
        idx.apply_compact(pending)
    idx.apply_compact(idx.prepare_compact())  # a fresh rebuild applies fine
    assert idx.epoch == 1


# -- tombstone edge cases ----------------------------------------------------


def test_delete_everything_then_reinsert():
    d = make_dataset("uniform", 50, 6, seed=86)
    idx = SimilarityIndex(d, _cfg(0.2))
    svc = QueryService(idx)
    oracle = ChurnOracle(d)
    q = _queries(d, seed=87)

    idx.delete(np.arange(50))
    oracle.delete(np.arange(50))
    assert idx.num_points == 0
    assert (svc.range_count(q, 0.2).counts == 0).all()
    assert svc.range_pairs(q, 0.2).pairs.shape == (0, 2)
    kn = svc.knn(q, 3)
    assert (kn.indices == -1).all() and np.isinf(kn.distances).all()

    # reinserting serves delta-only (every snapshot point is tombstoned)
    ids = idx.insert(d[:20])
    oracle.insert(d[:20])
    assert ids[0] == 50  # ids are never recycled
    _assert_matches_oracle(svc, oracle, q, 0.2)

    # compacting an all-tombstoned snapshot + delta still matches
    idx.compact()
    assert idx.num_points == oracle.live_count == 20
    _assert_matches_oracle(svc, oracle, q, 0.2)


def test_delete_then_reinsert_identical_coordinates():
    d = make_dataset("duplicated", 60, 6, seed=88)
    idx = SimilarityIndex(d, _cfg(0.1))
    svc = QueryService(idx)
    oracle = ChurnOracle(d)
    q = d[:12]

    # delete a point, reinsert the SAME coordinates: geometry is restored
    # but the pair ids must be the NEW global id, not the dead one
    idx.delete([7])
    oracle.delete([7])
    new_id = int(idx.insert(d[7:8])[0])
    assert int(oracle.insert(d[7:8])[0]) == new_id == 60
    _, rp, _ = _assert_matches_oracle(svc, oracle, q, 0.1)
    got_ids = set(rp.pairs[:, 1].tolist())
    assert 7 not in got_ids and new_id in got_ids
    idx.compact()
    _assert_matches_oracle(svc, oracle, q, 0.1)


def test_eps_zero_duplicate_join_under_churn():
    d = make_dataset("duplicated", 45, 6, seed=89)
    idx = SimilarityIndex(d, _cfg(0.0))
    svc = QueryService(idx)
    oracle = ChurnOracle(d)
    q = d[:10]

    base = svc.range_count(q, 0.0).counts
    np.testing.assert_array_equal(base, oracle.range_count(q, 0.0))
    idx.delete([0])  # one member of a duplicate group
    oracle.delete([0])
    _assert_matches_oracle(svc, oracle, q, 0.0)
    idx.insert(d[:1])  # an exact duplicate back, under a new id
    oracle.insert(d[:1])
    rc, _, _ = _assert_matches_oracle(svc, oracle, q, 0.0)
    np.testing.assert_array_equal(rc.counts, base)  # multiplicity restored


def test_delete_validation():
    d = make_dataset("uniform", 30, 6, seed=90)
    idx = SimilarityIndex(d, _cfg(0.2))
    with pytest.raises(KeyError):
        idx.delete([30])  # never allocated
    idx.delete([4])
    with pytest.raises(KeyError):
        idx.delete([4])  # already dead
    ids = idx.insert(d[:2])
    idx.delete(ids[:1])
    with pytest.raises(KeyError):
        idx.delete(ids[:1])  # delta ids die too
    assert idx.num_points == 30


# -- persistence of churn state ----------------------------------------------


def test_save_load_roundtrips_churn_state(tmp_path):
    d = make_dataset("clustered", 160, 8, seed=91)
    idx = SimilarityIndex(d[:140], _cfg(0.25))
    svc = QueryService(idx)
    oracle = ChurnOracle(d[:140])
    q = _queries(d, seed=92)
    idx.insert(d[140:])
    oracle.insert(d[140:])
    idx.delete([1, 17, 141])
    oracle.delete([1, 17, 141])
    idx.compact()
    ids = idx.insert(d[:6])
    oracle.insert(d[:6])
    idx.delete(ids[2:4])
    oracle.delete(ids[2:4])
    want = _assert_matches_oracle(svc, oracle, q, 0.25)

    loaded = SimilarityIndex.load(idx.save(tmp_path / "churn.idx"))
    assert loaded.epoch == idx.epoch == 1
    assert loaded.delta_size == idx.delta_size
    assert loaded.tombstone_count == idx.tombstone_count
    assert loaded.num_points == idx.num_points
    svc2 = QueryService(loaded)
    got = _assert_matches_oracle(svc2, oracle, q, 0.25)
    for w, g in zip(want, got):
        for field in ("counts", "pairs", "indices", "distances"):
            if hasattr(w, field):
                np.testing.assert_array_equal(getattr(w, field), getattr(g, field))

    # the reloaded index keeps allocating ids where the original left off
    np.testing.assert_array_equal(loaded.insert(d[:1]), idx.insert(d[:1]))
    loaded.compact()
    oracle.insert(d[:1])
    _assert_matches_oracle(QueryService(loaded), oracle, q, 0.25)


# -- delta-buffer spill policy -----------------------------------------------


def test_auto_compact_spill_policy_fires_and_answers_identical(tmp_path):
    """With ``auto_compact_fraction`` set, an insert stream crosses the
    spill threshold and compaction fires inside ``insert`` (epoch
    advances, delta drains) while every answer stays bit-identical to the
    churn oracle and to a twin index without the policy."""
    d = make_dataset("clustered", 160, 6, seed=37)
    pool = make_dataset("uniform", 120, 6, seed=38)
    eps = 0.25
    idx = SimilarityIndex(d, _cfg(eps), auto_compact_fraction=0.25)
    twin = SimilarityIndex(d, _cfg(eps))  # same stream, no spill policy
    svc, twin_svc = QueryService(idx), QueryService(twin)
    oracle = ChurnOracle(d)
    q = _queries(d, seed=39)

    fired = False
    for lo in range(0, len(pool), 20):
        batch = pool[lo : lo + 20]
        np.testing.assert_array_equal(idx.insert(batch), oracle.insert(batch))
        twin.insert(batch)
        if not fired and idx.auto_compactions:
            fired = True
            # the spill folded the delta into a fresh snapshot
            assert idx.delta_size == 0 and idx.epoch >= 1
        # the policy bounds the delta at every step of the stream
        assert idx.delta_size <= 0.25 * idx.num_points
        rc, rp, _ = _assert_matches_oracle(svc, oracle, q, eps)
        trc = twin_svc.range_count(q, eps)
        np.testing.assert_array_equal(rc.counts, trc.counts)
        np.testing.assert_array_equal(
            rp.pairs, twin_svc.range_pairs(q, eps).pairs
        )
    assert fired and idx.auto_compactions >= 1
    assert twin.epoch == 0 and twin.auto_compactions == 0
    assert idx.delta_size < twin.delta_size == len(pool)

    # the policy survives save/load and keeps firing afterwards
    loaded = SimilarityIndex.load(idx.save(tmp_path / "spill.idx"))
    assert loaded.auto_compact_fraction == 0.25
    epoch0 = loaded.epoch
    loaded.insert(make_dataset("uniform", 120, 6, seed=40))
    assert loaded.epoch > epoch0 and loaded.auto_compactions >= 1

    with pytest.raises(ValueError, match="auto_compact_fraction"):
        SimilarityIndex(d, _cfg(eps), auto_compact_fraction=0.0)


# -- interleaved stream property ---------------------------------------------


_STREAM_DIMS = 4
_STREAM_POOL = make_dataset("uniform", 200, _STREAM_DIMS, seed=93)
_STREAM_OPS = ["insert", "delete", "compact", "count", "pairs", "knn"]


def _run_stream_step(idx, svc, oracle, q, op, draw_int, draw_ids):
    """One interleaved-stream operation, checked against the oracle.

    ``draw_int(lo, hi)`` and ``draw_ids(live_count)`` abstract the choice
    source so the hypothesis property and the deterministic seeded stream
    share one body.
    """
    if op == "insert":
        lo = draw_int(0, 190)
        m = draw_int(1, 10)
        pts = _STREAM_POOL[lo : lo + m]
        np.testing.assert_array_equal(idx.insert(pts), oracle.insert(pts))
    elif op == "delete" and oracle.live_count:
        ids = oracle.live_ids[draw_ids(oracle.live_count)]
        assert idx.delete(ids) == oracle.delete(ids)
    elif op == "compact":
        idx.compact()
        assert idx.delta_size == 0 and idx.tombstone_count == 0
    elif op == "count":
        np.testing.assert_array_equal(
            svc.range_count(q, 0.3).counts, oracle.range_count(q, 0.3)
        )
    elif op == "pairs":
        assert pair_set(svc.range_pairs(q, 0.3).pairs) == pair_set(
            oracle.range_pairs(q, 0.3)
        )
    elif op == "knn":
        kn = svc.knn(q, 3)
        want_idx, want_dist = oracle.topk(q, 3)
        np.testing.assert_array_equal(kn.indices, want_idx)
        np.testing.assert_array_equal(kn.distances, want_dist)
    assert idx.num_points == oracle.live_count


def test_deterministic_interleaved_stream_matches_oracle():
    """A long seeded insert/delete/compact/query stream (always runs, even
    where hypothesis is unavailable) matches the oracle at every step."""
    rng = np.random.default_rng(94)
    base = _STREAM_POOL[:40]
    idx = SimilarityIndex(base, _cfg(0.3))
    svc = QueryService(idx)
    oracle = ChurnOracle(base)
    q = _STREAM_POOL[40:52]

    def draw_int(lo, hi):
        return int(rng.integers(lo, hi + 1))

    def draw_ids(live):
        m = int(rng.integers(1, min(8, live) + 1))
        return rng.choice(live, size=m, replace=False)

    for step in range(40):
        op = _STREAM_OPS[int(rng.integers(0, len(_STREAM_OPS)))]
        _run_stream_step(idx, svc, oracle, q, op, draw_int, draw_ids)
    _assert_matches_oracle(svc, oracle, q, 0.3)


if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(data=st.data())
    def test_interleaved_churn_stream_matches_oracle(data):
        """Any interleaving of insert / delete / compact / query operations
        answers exactly like the brute-force churn oracle at every step."""
        base = _STREAM_POOL[:40]
        idx = SimilarityIndex(base, _cfg(0.3))
        svc = QueryService(idx)
        oracle = ChurnOracle(base)
        q = _STREAM_POOL[40:52]

        def draw_int(lo, hi):
            return data.draw(st.integers(lo, hi))

        def draw_ids(live):
            pick = data.draw(
                st.lists(
                    st.integers(0, live - 1),
                    min_size=1,
                    max_size=min(8, live),
                    unique=True,
                )
            )
            return np.asarray(pick)

        n_ops = data.draw(st.integers(3, 8), label="n_ops")
        for step in range(n_ops):
            op = data.draw(st.sampled_from(_STREAM_OPS), label=f"op{step}")
            _run_stream_step(idx, svc, oracle, q, op, draw_int, draw_ids)
        _assert_matches_oracle(svc, oracle, q, 0.3)
