"""Multi-pod dry-run integration: one fast cell compiled in a subprocess
(the 512-device flag must precede jax init, so this cannot run in-process).
"""
import json
import os
import subprocess
import sys
import tempfile


def test_dryrun_single_cell_multipod():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    with tempfile.TemporaryDirectory() as out:
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "xlstm_125m", "--shape", "long_500k",
             "--multi-pod", "--out", out],
            capture_output=True, text=True, timeout=900,
            env={**{k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
                 "PYTHONPATH": src},
        )
        assert res.returncode == 0, res.stdout[-1500:] + res.stderr[-1500:]
        path = os.path.join(out, "xlstm_125m__long_500k__pod2.json")
        with open(path) as f:
            d = json.load(f)
        assert d["chips"] == 512
        assert d["compute_s"] >= 0 and d["memory_s"] > 0
        assert d["dominant"] in ("compute", "memory", "collective")
        # 512k-context decode state must be tiny (recurrent arch)
        assert (d["temp_bytes_per_chip"] or 0) < 16e9
