"""Ring self-join on 8 host devices (paper Sec. 6.3 -> ppermute).

Runs in a subprocess because the 8-device XLA flag must be set before jax
initializes (the main pytest process keeps the default 1 device).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, sys.argv[1])
    import numpy as np, jax
    from repro.core.distributed import ring_self_join_counts, ring_comm_elements
    from repro.core.brute import brute_counts
    from repro.data import exponential_dataset

    D = exponential_dataset(1003, 16, seed=5)   # non-divisible -> padding path
    eps = 0.06
    truth = brute_counts(D, eps)

    mesh1 = jax.make_mesh((8,), ("data",))
    c1 = ring_self_join_counts(D, eps, mesh1, "data", row_block=128)
    assert np.array_equal(c1, truth), "1-axis ring mismatch"

    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    c2 = ring_self_join_counts(D, eps, mesh2, ("pod", "data"), row_block=128)
    assert np.array_equal(c2, truth), "2-axis (multi-pod) ring mismatch"

    assert ring_comm_elements(1000, 8) == 7000   # (|p|-1)|D| (paper Sec. 6.3)
    print("RING_OK")
    """
)


def test_ring_self_join_8_devices():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, src],
        capture_output=True, text=True, timeout=600,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RING_OK" in out.stdout
