import os
import sys

# Tests default to the single real CPU device -- the 512-device flag is ONLY
# for launch/dryrun.py (see its module docstring).  CI's multi-device leg
# sets REPRO_TEST_DEVICES=8 to run the whole in-process suite against 8
# simulated host devices instead; subprocess tests (test_distributed,
# test_dist_engine, test_fused_ring, test_dryrun) set their own flag and
# strip the inherited one, so they behave identically on both legs.
_devices = os.environ.get("REPRO_TEST_DEVICES")
if _devices and _devices != "1":
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={int(_devices)}"
    )
else:
    os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # `import oracles` from any cwd

import pytest  # noqa: E402

from oracles import DATASET_CASES, DATASET_IDS  # noqa: E402


@pytest.fixture(params=DATASET_CASES, ids=DATASET_IDS)
def dataset_case(request):
    """(name, data, eps) from the shared correctness matrix (oracles.py)."""
    return request.param


# -- tier-1 duration budget --------------------------------------------------
# `--budget-seconds N` fails the session when the summed test call time
# exceeds N: the tripwire that keeps tier-1 fast (CI passes it explicitly,
# together with --durations, so the offenders are named in the same log).

_call_durations = []


def pytest_addoption(parser):
    parser.addoption(
        "--budget-seconds",
        type=float,
        default=None,
        help="fail the session if summed test call durations exceed this",
    )


def pytest_runtest_logreport(report):
    if report.when == "call":
        _call_durations.append((report.duration, report.nodeid))


def pytest_sessionfinish(session, exitstatus):
    budget = session.config.getoption("--budget-seconds")
    if budget is None or exitstatus != 0:
        return
    total = sum(d for d, _ in _call_durations)
    if total > budget:
        worst = sorted(_call_durations, reverse=True)[:10]
        lines = "\n".join(f"  {d:8.2f}s  {nid}" for d, nid in worst)
        print(
            f"\nDURATION BUDGET EXCEEDED: {total:.1f}s > {budget:.1f}s "
            f"budget; slowest tests:\n{lines}",
            file=sys.stderr,
        )
        session.exitstatus = 1
