import os
import sys

# Tests run on the single real CPU device -- the 512-device flag is ONLY for
# launch/dryrun.py (see its module docstring).
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
