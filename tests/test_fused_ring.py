"""Device-fused distributed ring join (DESIGN.md #7 addendum).

Parity matrix: the fused one-program ring must equal the host-driven
``DistributedSelfJoinEngine`` (its differential oracle), the single-device
``SelfJoinEngine``, and the brute-force oracle -- exactly, on 8 simulated
devices over both the 1-axis and the joint ("pod", "data") meshes, with a
non-divisible |D| (unequal shards -> padded tile tables + sentinel masking).

The 8-device matrix runs in a subprocess (the device-count flag must
precede jax init); the in-process tests cover the 1-device mesh, the
compile-once contract, and fused edge cases.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from oracles import brute_counts, make_dataset
from repro.core import (
    DistributedSelfJoinEngine,
    SelfJoinConfig,
    SelfJoinEngine,
)


def _mesh1():
    import jax

    return jax.make_mesh((1,), ("data",))


def test_fused_one_device_parity_and_compile_once():
    d = make_dataset("exponential", 403, 16, seed=5)
    cfg = SelfJoinConfig(eps=0.06, k=4, tile_size=16)
    de = DistributedSelfJoinEngine(d, cfg, mesh=_mesh1(), fused=True)
    res = de.count()
    np.testing.assert_array_equal(res.counts, brute_counts(d, cfg.eps))
    np.testing.assert_array_equal(
        res.counts, SelfJoinEngine(d, cfg).count().counts
    )
    assert res.stats.num_device_dispatches == 1
    assert de.fused_traces == 1 and de.fused_executions == 1
    # an eps sweep at or below the packed radius re-executes the SAME
    # compiled program: no retrace, no repack
    res_small = de.count(0.03)
    np.testing.assert_array_equal(res_small.counts, brute_counts(d, 0.03))
    assert de.fused_traces == 1 and de.fused_executions == 2


def test_fused_matches_host_driven_oracle_exactly():
    d = make_dataset("clustered", 301, 8, seed=7)
    cfg = SelfJoinConfig(eps=0.1, k=4, tile_size=16)
    fused = DistributedSelfJoinEngine(d, cfg, mesh=_mesh1(), fused=True).count()
    host = DistributedSelfJoinEngine(d, cfg, num_workers=1).count()
    np.testing.assert_array_equal(fused.counts, host.counts)
    # same index, same plans: the work counters agree too
    assert fused.stats.num_candidates == host.stats.num_candidates
    assert fused.stats.num_tile_pairs_evaluated == host.stats.num_tile_pairs_evaluated
    # the fused join is one dispatch; the host loop is one per chunk
    assert fused.stats.num_device_dispatches == 1
    assert host.stats.num_device_dispatches >= 1


@pytest.mark.parametrize(
    "kind,n,dims,eps",
    [
        ("duplicated", 90, 6, 0.0),      # eps == 0 duplicate join
        ("uniform", 1, 5, 0.1),          # single point
        ("constant_dims", 120, 6, 0.2),  # degenerate dimensions
    ],
)
def test_fused_edge_cases_one_device(kind, n, dims, eps):
    d = make_dataset(kind, n, dims, seed=3)
    cfg = SelfJoinConfig(eps=eps, k=3, tile_size=8, dim_block=8)
    de = DistributedSelfJoinEngine(d, cfg, mesh=_mesh1(), fused=True)
    np.testing.assert_array_equal(de.count().counts, brute_counts(d, eps))


def test_fused_pallas_backend_parity():
    # pallas_call has no shard_map replication rule: the fused program must
    # disable rep-checking for this backend (compat.shard_map check_rep)
    import dataclasses

    d = make_dataset("exponential", 180, 16, seed=9)
    cfg = SelfJoinConfig(
        eps=0.08, k=4, tile_size=16, dim_block=8, use_pallas=True
    )
    de = DistributedSelfJoinEngine(d, cfg, mesh=_mesh1(), fused=True)
    np.testing.assert_array_equal(de.count().counts, brute_counts(d, 0.08))
    jnp_cfg = dataclasses.replace(cfg, use_pallas=False)
    np.testing.assert_array_equal(
        de.count().counts,
        DistributedSelfJoinEngine(d, jnp_cfg, mesh=_mesh1(), fused=True)
        .count().counts,
    )


def test_fused_requires_matching_mesh():
    d = make_dataset("uniform", 64, 4, seed=1)
    with pytest.raises(ValueError, match="fused"):
        DistributedSelfJoinEngine(
            d, SelfJoinConfig(eps=0.1, k=2), num_workers=8, fused=True
        )
    with pytest.raises(ValueError, match="ring size"):
        DistributedSelfJoinEngine(
            d, SelfJoinConfig(eps=0.1, k=2), mesh=_mesh1(), num_workers=8,
            fused=True,
        )


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, sys.argv[1])
    sys.path.insert(0, sys.argv[2])
    import numpy as np, jax
    from oracles import brute_counts, make_dataset
    from repro.core import DistributedSelfJoinEngine, SelfJoinConfig, SelfJoinEngine

    D = make_dataset("exponential", 1003, 16, seed=5)  # 1003 % 8 != 0
    cfg = SelfJoinConfig(eps=0.06, k=4, tile_size=16)
    truth = brute_counts(D, cfg.eps)
    single = SelfJoinEngine(D, cfg).count().counts

    meshes = [
        (jax.make_mesh((8,), ("data",)), "data"),
        (jax.make_mesh((2, 4), ("pod", "data")), ("pod", "data")),
    ]
    for mesh, axes in meshes:
        for assignment in ("round_robin", "dynamic"):
            fused_eng = DistributedSelfJoinEngine(
                D, cfg, mesh=mesh, axes=axes, assignment=assignment, fused=True
            )
            fused = fused_eng.count()
            host = DistributedSelfJoinEngine(
                D, cfg, mesh=mesh, axes=axes, assignment=assignment
            ).count()
            tag = f"{axes}/{assignment}"
            assert np.array_equal(fused.counts, host.counts), f"{tag}: fused != host"
            assert np.array_equal(fused.counts, single), f"{tag}: fused != single"
            assert np.array_equal(fused.counts, truth), f"{tag}: fused != brute"
            assert fused_eng.fused_traces == 1, f"{tag}: retraced"
            assert fused.stats.num_device_dispatches == 1
            assert fused.stats.num_workers == 8 and fused.stats.num_rounds == 8
            assert fused.stats.comm_elements == 7 * 1003

    # eps sweep on one mesh: same program, still exact at every radius
    eng = DistributedSelfJoinEngine(D, cfg, mesh=meshes[0][0], fused=True)
    for eps in (0.06, 0.04, 0.02):
        assert np.array_equal(eng.count(eps).counts, brute_counts(D, eps)), eps
    assert eng.fused_traces == 1 and eng.fused_executions == 3

    # workers with zero query batches (|D| < |p|), on a real 8-ring
    tiny = make_dataset("uniform", 5, 4, seed=4)
    tcfg = SelfJoinConfig(eps=0.3, k=2, tile_size=8)
    teng = DistributedSelfJoinEngine(tiny, tcfg, mesh=meshes[0][0], fused=True)
    assert np.array_equal(teng.count().counts, brute_counts(tiny, 0.3))
    print("FUSED_RING_OK")
    """
)


def test_fused_ring_8_devices():
    here = os.path.dirname(__file__)
    src = os.path.join(here, "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, src, here],
        capture_output=True, text=True, timeout=600,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FUSED_RING_OK" in out.stdout
