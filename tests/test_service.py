"""The online query service (DESIGN.md #8): SimilarityIndex + QueryService.

Correctness is pinned exactly against the shared oracles (``bipartite_counts``
for range queries, ``brute_topk`` for kNN -- float64, ties by data id) over
every dataset kind in the shared matrix, and the serving contracts are pinned
as hard counters: a mixed-shape request stream compiles at most one count
executable per shape bucket (``ServiceStats.num_traces``), and an index
reloaded from disk serves bit-identically to the one that was saved.
"""
import numpy as np
import pytest

from oracles import (
    bipartite_counts,
    brute_topk,
    make_dataset,
    pair_set,
)
from repro.core import SelfJoinConfig, select_k
from repro.join import QueryService, SimilarityIndex


def _cfg(eps, **kw):
    kw.setdefault("k", 4)
    kw.setdefault("tile_size", 16)
    kw.setdefault("dim_block", 8)
    return SelfJoinConfig(eps=eps, **kw)


def _queries(d, seed, n_extra=24):
    """Mixed batch: dataset rows (exact hits, duplicates) + fresh points."""
    extra = make_dataset("uniform", n_extra, d.shape[1], seed=seed)
    return np.concatenate([d[: min(41, len(d))], extra])


# -- range queries -----------------------------------------------------------


def test_range_count_matches_oracle_and_engine(dataset_case):
    name, d, eps = dataset_case
    idx = SimilarityIndex(d, _cfg(eps))
    svc = QueryService(idx)
    q = _queries(d, seed=31)
    res = svc.range_count(q, eps)
    np.testing.assert_array_equal(res.counts, bipartite_counts(q, d, eps))
    # the service's bucket-padded path equals the engine's unpadded one
    np.testing.assert_array_equal(
        res.counts, idx.engine.count_query(q, eps).counts
    )
    assert res.stats.num_queries == q.shape[0]
    assert res.stats.bucket >= q.shape[0]
    assert res.stats.num_results == int(res.counts.sum())


def test_range_pairs_matches_oracle(dataset_case):
    name, d, eps = dataset_case
    svc = QueryService(SimilarityIndex(d, _cfg(eps)))
    q = _queries(d, seed=32)
    res = svc.range_pairs(q, eps)
    d2 = (
        (q[:, None, :].astype(np.float64) - d[None, :, :].astype(np.float64))
        ** 2
    ).sum(-1)
    want = set(zip(*map(list, np.nonzero(d2 <= np.float64(eps) ** 2))))
    assert pair_set(res.pairs) == want
    np.testing.assert_array_equal(res.counts, bipartite_counts(q, d, eps))
    # rows are lexsorted: deterministic across buffer layouts
    assert res.pairs.shape[0] == len(want)
    if res.pairs.shape[0] > 1:
        keys = res.pairs[:, 0].astype(np.int64) * (len(d) + 1) + res.pairs[:, 1]
        assert (np.diff(keys) > 0).all()


def test_smaller_eps_than_index_reuses_it(dataset_case):
    name, d, eps = dataset_case
    idx = SimilarityIndex(d, _cfg(eps))
    svc = QueryService(idx)
    q = _queries(d, seed=33)
    res = svc.range_count(q, eps / 2)
    np.testing.assert_array_equal(res.counts, bipartite_counts(q, d, eps / 2))
    assert res.stats.index_rebuilds == 0


# -- kNN ---------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 5])
def test_knn_matches_bruteforce_topk(dataset_case, k):
    name, d, eps = dataset_case
    svc = QueryService(SimilarityIndex(d, _cfg(eps)))
    q = _queries(d, seed=34)
    res = svc.knn(q, k)
    want_idx, want_dist = brute_topk(q, d, k)
    np.testing.assert_array_equal(res.indices, want_idx)
    np.testing.assert_array_equal(res.distances, want_dist)
    assert res.stats.eps_rounds >= 1
    # the final radius really held >= k candidates for every query
    assert (res.counts >= min(k, len(d))).all()


def test_knn_k_at_least_dataset_size_pads():
    d = make_dataset("uniform", 23, 6, seed=40)
    svc = QueryService(SimilarityIndex(d, _cfg(0.2)))
    q = _queries(d, seed=41)[:9]
    k = 40  # > |D|
    res = svc.knn(q, k)
    want_idx, want_dist = brute_topk(q, d, k)
    np.testing.assert_array_equal(res.indices, want_idx)
    np.testing.assert_array_equal(res.distances, want_dist)
    assert (res.indices[:, 23:] == -1).all()
    assert np.isinf(res.distances[:, 23:]).all()
    # reaching every point forced the radius up to the full-domain cap
    assert res.stats.eps_rounds > 1


def test_knn_duplicated_points_break_ties_by_id():
    d = make_dataset("duplicated", 90, 6, seed=42)
    svc = QueryService(SimilarityIndex(d, _cfg(0.1)))
    q = d[:31]  # exact duplicates of indexed points: maximal tie pressure
    res = svc.knn(q, 7)
    want_idx, want_dist = brute_topk(q, d, 7)
    np.testing.assert_array_equal(res.indices, want_idx)
    np.testing.assert_array_equal(res.distances, want_dist)


def test_knn_eps_expansion_from_tiny_radius():
    # queries sit far from the data: the initial radius finds nothing and
    # the expansion loop must double out to the bounding-box diagonal cap
    d = make_dataset("clustered", 120, 8, seed=43)
    svc = QueryService(SimilarityIndex(d, _cfg(0.01)))
    q = np.ones((5, 8), np.float32)  # corner of the domain
    res = svc.knn(q, 3)
    want_idx, want_dist = brute_topk(q, d, 3)
    np.testing.assert_array_equal(res.indices, want_idx)
    np.testing.assert_array_equal(res.distances, want_dist)
    assert res.stats.eps_rounds > 3
    assert res.stats.index_rebuilds >= 1  # grew past the build radius


def test_knn_eps0_index_still_terminates():
    # an index built at eps == 0 (duplicate join) must still answer kNN:
    # doubling from 0 would never grow, so the service seeds from the cap
    d = make_dataset("duplicated", 60, 6, seed=44)
    svc = QueryService(SimilarityIndex(d, _cfg(0.0)))
    q = d[:8]
    res = svc.knn(q, 4)
    want_idx, want_dist = brute_topk(q, d, 4)
    np.testing.assert_array_equal(res.indices, want_idx)
    np.testing.assert_array_equal(res.distances, want_dist)


# -- serving contracts -------------------------------------------------------


def test_compile_reuse_contract_mixed_stream():
    """100 mixed-shape range requests compile <= one program per bucket."""
    d = make_dataset("exponential", 397, 16, seed=50)
    svc = QueryService(SimilarityIndex(d, _cfg(0.08)))
    pool = _queries(d, seed=51, n_extra=300)
    rng = np.random.default_rng(52)
    for i in range(100):
        nq = int(rng.integers(1, 300))
        eps = float(rng.choice([0.08, 0.05, 0.031, 0.017]))  # all <= build eps
        q = pool[rng.choice(pool.shape[0], size=nq, replace=False)]
        res = svc.range_count(q, eps)
        np.testing.assert_array_equal(res.counts, bipartite_counts(q, d, eps))
    assert svc.total.num_requests == 100
    assert svc.total.index_rebuilds == 0
    # the contract: one count executable per shape bucket, nothing more
    assert svc.total.num_traces <= len(svc.buckets_used)
    assert len(svc.buckets_used) <= 6  # pow2 buckets covering 1..299 from 16

    # a second identical-shape stream retraces NOTHING
    before = svc.total.num_traces
    for nq in (3, 40, 100, 250):
        svc.range_count(pool[:nq], 0.06)
    assert svc.total.num_traces == before


def test_pairs_and_knn_trace_keys_are_bounded():
    d = make_dataset("uniform", 211, 8, seed=53)
    svc = QueryService(SimilarityIndex(d, _cfg(0.3)))
    q = _queries(d, seed=54)
    first = svc.range_pairs(q, 0.3)
    traces_after_first = svc.total.num_traces
    # same bucket, same pow2 pairs capacity: the repeat adds zero traces
    again = svc.range_pairs(q, 0.3)
    assert svc.total.num_traces == traces_after_first
    np.testing.assert_array_equal(first.pairs, again.pairs)
    kn1 = svc.knn(q, 4)
    knn_traces = svc.total.num_traces
    kn2 = svc.knn(q, 4)
    np.testing.assert_array_equal(kn1.indices, kn2.indices)
    assert svc.total.num_traces == knn_traces  # expansion path fully cached


def test_index_stays_pinned_at_build_radius_after_knn():
    """A far-query kNN must not degrade later requests (epoch pinning):
    over-radius rounds serve from TEMPORARY rebuilt snapshots and the
    resident snapshot -- and every warm executable -- is never touched."""
    d = make_dataset("clustered", 300, 8, seed=58)
    svc = QueryService(SimilarityIndex(d, _cfg(0.05)))
    q = _queries(d, seed=59)
    base = svc.range_count(q, 0.05)
    warm_traces = svc.total.num_traces

    far = np.ones((3, 8), np.float32)  # forces expansion out to the cap
    kn = svc.knn(far, 2)
    assert kn.stats.index_rebuilds >= 2          # one temp snapshot per round
    assert svc.index.index_eps == 0.05           # the resident never moved

    after = svc.range_count(q, 0.05)
    np.testing.assert_array_equal(after.counts, base.counts)
    # the untouched resident kept its filtering power and warm executable
    assert after.stats.num_candidates == base.stats.num_candidates
    assert after.stats.num_traces == 0
    assert svc.total.num_traces >= warm_traces   # knn traced; range did not


def test_mixed_stream_straddling_tier_boundary_compile_contract():
    """An ``execution="auto"`` stream that flips tiers per request still
    compiles at most ONE count executable per shape bucket PER TIER.

    Hot batches (cluster members, maximal grid fan-out) dispatch dense;
    cold batches (empty-corner points, no adjacency) dispatch indexed --
    same index, same bucket.  The tile tables differ per tier, so each
    tier owns its executable; the contract bounds the total at
    buckets x tiers and pins a repeat stream at zero retraces.
    """
    d = make_dataset("clustered", 300, 4, seed=60)
    svc = QueryService(SimilarityIndex(d, _cfg(0.15, execution="auto")))
    hot = d[:48]
    cold = np.full((48, 4), 0.99, np.float32)
    seen = set()
    for _ in range(3):  # repeats must hit warm executables on both tiers
        for q, want_tier in ((hot, "dense"), (cold, "indexed")):
            res = svc.range_count(q, 0.15)
            np.testing.assert_array_equal(
                res.counts, bipartite_counts(q, d, 0.15)
            )
            assert res.stats.execution == want_tier
            assert res.stats.cost_indexed > 0 and res.stats.cost_dense > 0
            seen.add(res.stats.execution)
    assert seen == {"dense", "indexed"}
    assert svc.total.execution == "mixed"  # the stream really straddled
    assert svc.total.num_requests == 6
    # <= one executable per (bucket, tier); both batches share one bucket
    assert len(svc.buckets_used) == 1
    assert svc.total.num_traces <= 2 * len(svc.buckets_used)

    # a second identical straddling stream retraces NOTHING
    before = svc.total.num_traces
    for q in (hot, cold, hot, cold):
        svc.range_count(q, 0.15)
    assert svc.total.num_traces == before

    # pairs mode honours the same per-tier dispatch and stays exact
    for q in (hot, cold):
        rp = svc.range_pairs(q, 0.15)
        np.testing.assert_array_equal(rp.counts, bipartite_counts(q, d, 0.15))


@pytest.mark.parametrize("mode", ["indexed", "dense", "auto"])
def test_save_load_roundtrips_execution_mode_bit_identically(tmp_path, mode):
    d = make_dataset("exponential", 211, 16, seed=62)
    idx = SimilarityIndex(d, _cfg(0.06, execution=mode))
    svc = QueryService(idx)
    q = _queries(d, seed=63)
    want = svc.range_count(q, 0.06)
    want_pairs = svc.range_pairs(q, 0.06).pairs

    loaded = SimilarityIndex.load(idx.save(tmp_path / f"exec_{mode}"))
    assert loaded.config == idx.config
    assert loaded.config.execution == mode  # the mode bit survived the disk
    svc2 = QueryService(loaded)
    got = svc2.range_count(q, 0.06)
    np.testing.assert_array_equal(got.counts, want.counts)
    np.testing.assert_array_equal(svc2.range_pairs(q, 0.06).pairs, want_pairs)
    # the reloaded index makes the SAME dispatch decision with the SAME costs
    assert got.stats.execution == want.stats.execution
    assert got.stats.cost_indexed == want.stats.cost_indexed
    assert got.stats.cost_dense == want.stats.cost_dense


def test_index_save_load_serves_bit_identically(tmp_path, dataset_case):
    name, d, eps = dataset_case
    idx = SimilarityIndex(d, _cfg(eps))
    svc = QueryService(idx)
    q = _queries(d, seed=55)
    want_counts = svc.range_count(q, eps).counts
    want_pairs = svc.range_pairs(q, eps).pairs
    want_knn = svc.knn(q, 3)

    path = idx.save(tmp_path / f"{name}.idx")
    loaded = SimilarityIndex.load(path)
    assert loaded.config == idx.config
    assert loaded.index_eps == idx.index_eps
    if idx.perm is not None:
        np.testing.assert_array_equal(loaded.perm, idx.perm)
    svc2 = QueryService(loaded)
    np.testing.assert_array_equal(svc2.range_count(q, eps).counts, want_counts)
    np.testing.assert_array_equal(svc2.range_pairs(q, eps).pairs, want_pairs)
    got_knn = svc2.knn(q, 3)
    np.testing.assert_array_equal(got_knn.indices, want_knn.indices)
    np.testing.assert_array_equal(got_knn.distances, want_knn.distances)


def test_auto_k_selection_is_baked_into_the_index(tmp_path):
    d = make_dataset("exponential", 500, 16, seed=56)
    ks = [2, 3, 4, 6]
    idx = SimilarityIndex(d, _cfg(0.05, k=2), k_candidates=ks)
    want_k = select_k(d, 0.05, ks, sample_frac=0.01, tile_size=16)
    assert idx.config.k == want_k
    loaded = SimilarityIndex.load(idx.save(tmp_path / "auto_k"))
    assert loaded.config.k == want_k  # no re-tuning on restart


def test_empty_edges():
    d = make_dataset("uniform", 50, 6, seed=57)
    svc = QueryService(SimilarityIndex(d, _cfg(0.2)))
    empty_q = np.zeros((0, 6), np.float32)
    assert svc.range_count(empty_q).counts.shape == (0,)
    assert svc.range_pairs(empty_q).pairs.shape == (0, 2)
    assert svc.knn(empty_q, 3).indices.shape == (0, 3)
    res = svc.knn(d[:4], 0)
    assert res.indices.shape == (4, 0)

    empty_idx = SimilarityIndex(np.zeros((0, 6), np.float32), _cfg(0.2))
    esvc = QueryService(empty_idx)
    q = d[:5]
    assert (esvc.range_count(q).counts == 0).all()
    assert esvc.range_pairs(q).pairs.shape == (0, 2)
    kn = esvc.knn(q, 3)
    assert (kn.indices == -1).all() and np.isinf(kn.distances).all()
