"""Distributed-tier edge cases (DESIGN.md #7), both assignment modes.

Degenerate inputs the BSP machinery must survive without special-casing by
the caller: eps == 0 (duplicate join), a single point spread over many
workers, k exceeding the dimensionality, workers that own zero query
batches, and the empty dataset.  Plus the pairs-buffer overflow-retry
regression: after auto-grow the reported counts and |R| are exact.
"""
import numpy as np
import pytest

from oracles import brute_counts, brute_pairs, make_dataset, pair_set
from repro.core import (
    DistributedSelfJoinEngine,
    SelfJoinConfig,
    SelfJoinEngine,
)
from repro.core import batching as batching_mod

MODES = ["round_robin", "dynamic"]


@pytest.mark.parametrize("assignment", MODES)
def test_dist_eps_zero_duplicate_join(assignment):
    d = make_dataset("duplicated", 90, 6, seed=1)
    cfg = SelfJoinConfig(eps=0.0, k=3, tile_size=8, dim_block=8)
    de = DistributedSelfJoinEngine(d, cfg, num_workers=4, assignment=assignment)
    res = de.count()
    np.testing.assert_array_equal(res.counts, brute_counts(d, 0.0))
    assert (res.counts >= 1).all()          # self-match survives eps == 0
    assert res.counts.max() >= 3            # and so do the duplicate groups


@pytest.mark.parametrize("assignment", MODES)
def test_dist_single_point_many_workers(assignment):
    d = make_dataset("uniform", 1, 5, seed=2)
    cfg = SelfJoinConfig(eps=0.1, k=3)
    de = DistributedSelfJoinEngine(d, cfg, num_workers=8, assignment=assignment)
    res = de.count()
    assert res.counts.tolist() == [1]
    assert res.stats.num_rounds == 8


@pytest.mark.parametrize("assignment", MODES)
def test_dist_k_exceeds_num_dims(assignment):
    d = make_dataset("uniform", 120, 3, seed=3)
    cfg = SelfJoinConfig(eps=0.2, k=7, tile_size=8)   # k > n: clamps to n
    de = DistributedSelfJoinEngine(d, cfg, num_workers=4, assignment=assignment)
    res = de.count()
    np.testing.assert_array_equal(res.counts, brute_counts(d, 0.2))
    assert res.stats.k == 3


@pytest.mark.parametrize("assignment", MODES)
def test_dist_empty_query_batches(assignment):
    # more workers than points: several workers own zero query points
    d = make_dataset("uniform", 5, 4, seed=4)
    cfg = SelfJoinConfig(eps=0.3, k=2, tile_size=8)
    de = DistributedSelfJoinEngine(d, cfg, num_workers=8, assignment=assignment)
    assert any(de.worker_query_index(k).size == 0 for k in range(8))
    np.testing.assert_array_equal(de.count().counts, brute_counts(d, 0.3))


def test_dist_empty_dataset():
    d = np.zeros((0, 4), np.float32)
    de = DistributedSelfJoinEngine(d, SelfJoinConfig(eps=0.1, k=2), num_workers=4)
    res = de.count()
    assert res.counts.shape == (0,)
    assert res.stats.num_results == 0


def test_pairs_overflow_retry_reports_exact_counts(monkeypatch):
    """Regression: counts/|R| stay exact through the auto-grow retry path."""
    d = make_dataset("uniform", 350, 4, seed=5)
    eps = 0.45
    # sabotage the size estimate so the first pass overflows and retries
    monkeypatch.setattr(batching_mod, "estimate_result_size", lambda *a, **k: 1)
    eng = SelfJoinEngine(d, SelfJoinConfig(eps=eps, k=2, tile_size=16, dim_block=8))
    res = eng.pairs()
    assert res.stats.overflow_retries > 0
    truth = brute_counts(d, eps)
    np.testing.assert_array_equal(res.counts, truth)
    assert res.stats.num_results == int(truth.sum()) == len(res.pairs)
    assert pair_set(res.pairs) == pair_set(brute_pairs(d, eps))
