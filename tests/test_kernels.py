"""Pallas distance-tile kernel vs the pure-jnp oracle (interpret mode).

Shape/dtype sweep per the deliverable: tile sizes, dimension counts (with
padding), dim-block splits; exactness via 1/64-quantized coordinates (all
squared distances exactly representable in fp32 in both the direct and the
matmul formulation).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.distance_tile import tile_pair_distance
from repro.kernels.ref import ref_tile_counts, ref_tile_mask


def _mk(num_tiles, t, n, seed, quantize=True):
    rng = np.random.default_rng(seed)
    pts = rng.random((num_tiles, t, n), dtype=np.float32)
    if quantize:
        pts = np.round(pts * 64) / 64.0
    lens = rng.integers(1, t + 1, size=num_tiles).astype(np.int32)
    for i in range(num_tiles):
        pts[i, lens[i]:] = 0.0
    p = rng.integers(0, num_tiles, size=(24, 2)).astype(np.int32)
    return pts.astype(np.float32), lens, p[:, 0], p[:, 1]


@pytest.mark.parametrize("t", [8, 16, 32])
@pytest.mark.parametrize("n,db", [(8, 8), (16, 8), (32, 16), (64, 32)])
def test_kernel_counts_match_ref(t, n, db):
    pts, lens, pa, pb = _mk(6, t, n, seed=t * 100 + n)
    eps = 0.31
    counts, skipped = tile_pair_distance(
        jnp.asarray(pts), jnp.asarray(lens), jnp.asarray(pa), jnp.asarray(pb),
        eps=eps, dim_block=db, interpret=True,
    )
    ref = ref_tile_counts(jnp.asarray(pts), jnp.asarray(lens),
                          jnp.asarray(pa), jnp.asarray(pb), eps)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref))
    assert skipped.shape == (24, 1)


@pytest.mark.parametrize("t", [8, 16])
def test_kernel_mask_matches_ref(t):
    pts, lens, pa, pb = _mk(5, t, 16, seed=t)
    eps = 0.4
    _, _, mask = tile_pair_distance(
        jnp.asarray(pts), jnp.asarray(lens), jnp.asarray(pa), jnp.asarray(pb),
        eps=eps, dim_block=8, interpret=True, return_mask=True,
    )
    ref = ref_tile_mask(jnp.asarray(pts), jnp.asarray(lens),
                        jnp.asarray(pa), jnp.asarray(pb), eps)
    np.testing.assert_array_equal(np.asarray(mask).astype(bool), np.asarray(ref))


def test_kernel_shortcircuit_skips_far_tiles():
    """Two clusters far apart: cross-tile pairs must skip later dim blocks."""
    t, n = 8, 32
    pts = np.zeros((2, t, n), np.float32)
    pts[0] = 0.0
    pts[1] = 0.9
    lens = np.full(2, t, np.int32)
    pa = np.array([0, 0, 1], np.int32)
    pb = np.array([0, 1, 1], np.int32)
    counts, skipped = tile_pair_distance(
        jnp.asarray(pts), jnp.asarray(lens), jnp.asarray(pa), jnp.asarray(pb),
        eps=0.05, dim_block=8, interpret=True,
    )
    c = np.asarray(counts)
    s = np.asarray(skipped)[:, 0]
    assert c[0].sum() == t * t  # identical points all match
    assert c[1].sum() == 0      # cross pair: no matches...
    assert s[1] == 3            # ...decided after the first of 4 blocks
    assert s[0] == 0


def test_kernel_matches_jnp_backend_exactly():
    pts, lens, pa, pb = _mk(8, 16, 24, seed=42)
    # n=24 pads to 32 with dim_block=8 -> 4 blocks (padding block included)
    pts_pad = np.zeros((8, 16, 24), np.float32)
    pts_pad[:] = pts
    tiles, lens32 = ops.make_tiles(
        pts_pad.reshape(-1, 24), np.arange(0, 8 * 16, 16, dtype=np.int64),
        np.asarray(lens, np.int64), 16, 8,
    )
    for backend in ("jnp", "pallas"):
        c, s = ops.tile_counts(
            tiles, lens32, pa, pb, eps=0.25, dim_block=8,
            shortc=True, backend=backend, chunk=16,
        )
        if backend == "jnp":
            base_c, base_s = c, s
        else:
            np.testing.assert_array_equal(c, base_c)
            np.testing.assert_array_equal(s, base_s)


def test_unquantized_f32_tolerance():
    """Unquantized coords: matmul vs direct form may differ only at the
    eps boundary; counts must agree when no distance is within 1e-5 of eps."""
    rng = np.random.default_rng(3)
    pts = rng.random((4, 8, 16), dtype=np.float32)
    lens = np.full(4, 8, np.int32)
    pa = np.array([0, 1, 2], np.int32)
    pb = np.array([1, 2, 3], np.int32)
    eps = 0.437  # generic value; boundary ties have measure ~0
    counts, _ = tile_pair_distance(
        jnp.asarray(pts), jnp.asarray(lens), jnp.asarray(pa), jnp.asarray(pb),
        eps=eps, dim_block=16, interpret=True,
    )
    ref = ref_tile_counts(jnp.asarray(pts), jnp.asarray(lens),
                          jnp.asarray(pa), jnp.asarray(pb), eps)
    diff = np.abs(np.asarray(counts) - np.asarray(ref)).sum()
    assert diff <= 2  # allow boundary straddle only
