"""Grid-indexed distributed self-join (DESIGN.md #7, paper Sec. 6).

In-process tests cover the bipartite query sub-plan and the BSP ring
schedule on one device; the subprocess test runs the engine against meshes
of 8 simulated host devices (the device-count flag must precede jax init).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from oracles import bipartite_counts as _bipartite_truth, brute_counts
from repro.core import (
    DistributedSelfJoinEngine,
    SelfJoinConfig,
    SelfJoinEngine,
)
from repro.data import clustered_dataset, exponential_dataset

CFG = SelfJoinConfig(eps=0.06, k=4, tile_size=16)


def test_count_query_matches_brute_bipartite():
    d = exponential_dataset(900, 16, seed=3)
    q = exponential_dataset(400, 16, seed=11)
    eng = SelfJoinEngine(d, CFG)
    res = eng.count_query(q)
    assert np.array_equal(res.counts, _bipartite_truth(q, d, CFG.eps))
    # index filtering active: fewer candidates than the dense |Q| x |D|
    assert 0 < res.stats.num_candidates < q.shape[0] * d.shape[0]


def test_count_query_self_equals_count():
    d = clustered_dataset(700, 8, seed=2)
    cfg = SelfJoinConfig(eps=0.08, k=5, tile_size=16)
    eng = SelfJoinEngine(d, cfg)
    assert np.array_equal(eng.count_query(d).counts, eng.count().counts)


def test_count_query_smaller_eps_reuses_index():
    d = exponential_dataset(600, 16, seed=4)
    q = exponential_dataset(200, 16, seed=5)
    eng = SelfJoinEngine(d, CFG)
    res = eng.count_query(q, eps=0.03)   # index built at 0.06, queried below
    assert np.array_equal(res.counts, _bipartite_truth(q, d, 0.03))


def test_count_query_empty_query():
    d = exponential_dataset(100, 16, seed=1)
    eng = SelfJoinEngine(d, CFG)
    assert eng.count_query(np.zeros((0, 16), np.float32)).counts.shape == (0,)


def test_dist_engine_parity_nondivisible():
    d = exponential_dataset(1003, 16, seed=5)   # 1003 % 8 != 0 (uneven shards)
    truth = brute_counts(d, CFG.eps)
    de = DistributedSelfJoinEngine(d, CFG, num_workers=8)
    res = de.count()
    assert np.array_equal(res.counts, truth)
    assert np.array_equal(res.counts, SelfJoinEngine(d, CFG).count().counts)
    s = res.stats
    assert s.num_workers == 8 and s.num_rounds == 8
    assert s.num_candidates_dense == 1003 * 1003
    assert 0 < s.num_candidates < s.num_candidates_dense
    assert s.comm_elements == 7 * 1003


def test_dist_engine_single_worker_equals_engine():
    d = exponential_dataset(500, 16, seed=7)
    de = DistributedSelfJoinEngine(d, CFG, num_workers=1)
    assert np.array_equal(de.count().counts, SelfJoinEngine(d, CFG).count().counts)


def test_dist_engine_dynamic_assignment_parity_and_balance():
    d = exponential_dataset(800, 16, seed=9)
    truth = brute_counts(d, CFG.eps)
    rr = DistributedSelfJoinEngine(d, CFG, num_workers=8, num_batches=32)
    dyn = DistributedSelfJoinEngine(
        d, CFG, num_workers=8, num_batches=32, assignment="dynamic"
    )
    assert np.array_equal(rr.count().counts, truth)
    assert np.array_equal(dyn.count().counts, truth)
    # LPT on cost estimates never loads the max worker more than round-robin
    assert dyn.worker_loads().max() <= rr.worker_loads().max() + 1e-9


def test_dist_engine_ring_schedule_covers_all_shards():
    de = DistributedSelfJoinEngine(
        exponential_dataset(64, 4, seed=0), SelfJoinConfig(eps=0.1, k=2),
        num_workers=4,
    )
    seen = {k: set() for k in range(4)}
    for round_sched in de.ring_schedule():
        for k, j in round_sched:
            seen[k].add(j)
    assert all(seen[k] == {0, 1, 2, 3} for k in range(4))


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, sys.argv[1])
    import numpy as np, jax
    from repro.core import DistributedSelfJoinEngine, SelfJoinConfig, SelfJoinEngine
    from repro.core.brute import brute_counts
    from repro.data import exponential_dataset

    D = exponential_dataset(1003, 16, seed=5)   # non-divisible -> uneven shards
    eps = 0.06
    cfg = SelfJoinConfig(eps=eps, k=4, tile_size=16)
    truth = brute_counts(D, eps)

    mesh1 = jax.make_mesh((8,), ("data",))
    de1 = DistributedSelfJoinEngine(D, cfg, mesh=mesh1)
    r1 = de1.count()
    assert de1.num_workers == 8
    assert np.array_equal(r1.counts, truth), "1-axis mesh mismatch"
    assert np.array_equal(r1.counts, SelfJoinEngine(D, cfg).count().counts)
    assert 0 < r1.stats.num_candidates < r1.stats.num_candidates_dense

    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    de2 = DistributedSelfJoinEngine(D, cfg, mesh=mesh2, axes=("pod", "data"))
    assert de2.num_workers == 8
    assert np.array_equal(de2.count().counts, truth), "2-axis mesh mismatch"

    dyn = DistributedSelfJoinEngine(
        D, cfg, mesh=mesh1, num_batches=32, assignment="dynamic"
    )
    assert np.array_equal(dyn.count().counts, truth), "dynamic mismatch"
    rr = DistributedSelfJoinEngine(D, cfg, mesh=mesh1, num_batches=32)
    assert dyn.worker_loads().max() <= rr.worker_loads().max() + 1e-9
    print("DIST_ENGINE_OK")
    """
)


def test_dist_engine_8_devices():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, src],
        capture_output=True, text=True, timeout=600,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DIST_ENGINE_OK" in out.stdout
