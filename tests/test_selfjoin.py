"""Correctness of the self-join against the brute-force oracle (Sec. 3.1).

The base dataset matrix lives in ``tests/oracles.py`` (shared with the
engine and distributed tiers); this file adds the paper-specific regimes
(64-dim exponential, low-variance-prefix clustered) on top.
"""
import dataclasses

import numpy as np
import pytest

from oracles import DATASET_CASES, brute_counts, brute_pairs
from repro.core import SelfJoinConfig, self_join
from repro.core.ego import ego_join_counts
from repro.core.tuning import estimate_k_costs, select_k
from repro.data import clustered_dataset, exponential_dataset

DATASETS = DATASET_CASES + [
    ("exp64", exponential_dataset(400, 64, seed=2), 0.16),
    ("lowvar", clustered_dataset(400, 24, low_variance_dims=12, seed=5), 0.3),
]


@pytest.mark.parametrize("name,d,eps", DATASETS, ids=[x[0] for x in DATASETS])
@pytest.mark.parametrize("sortidu", [False, True])
@pytest.mark.parametrize("shortc", [False, True])
def test_counts_match_brute(name, d, eps, sortidu, shortc):
    truth = brute_counts(d, eps)
    cfg = SelfJoinConfig(
        eps=eps, k=4, sortidu=sortidu, shortc=shortc, tile_size=16, dim_block=8
    )
    res = self_join(d, cfg)
    np.testing.assert_array_equal(res.counts, truth)
    assert res.stats.num_results == int(truth.sum())


@pytest.mark.parametrize("k", [1, 2, 3, 6, 10])
def test_counts_match_brute_all_k(k):
    d = exponential_dataset(500, 16, seed=7)
    eps = 0.06
    truth = brute_counts(d, eps)
    res = self_join(d, SelfJoinConfig(eps=eps, k=k, tile_size=16))
    np.testing.assert_array_equal(res.counts, truth)


@pytest.mark.parametrize("reorder", [False, True])
def test_reorder_changes_plan_not_result(reorder):
    d = clustered_dataset(400, 24, low_variance_dims=12, seed=8)
    eps = 0.3
    truth = brute_counts(d, eps)
    res = self_join(d, SelfJoinConfig(eps=eps, k=3, reorder=reorder, tile_size=16))
    np.testing.assert_array_equal(res.counts, truth)


def test_reorder_improves_filtering_on_low_variance_prefix():
    """Paper Fig. 6b: low-variance leading dims -> REORDER prunes candidates."""
    d = clustered_dataset(800, 24, low_variance_dims=12, seed=9)
    eps = 0.25
    on = self_join(d, SelfJoinConfig(eps=eps, k=4, reorder=True, tile_size=16))
    off = self_join(d, SelfJoinConfig(eps=eps, k=4, reorder=False, tile_size=16))
    assert on.stats.num_candidates < off.stats.num_candidates


def test_sortidu_prunes():
    d = exponential_dataset(800, 32, seed=10)
    eps = 0.08
    on = self_join(d, SelfJoinConfig(eps=eps, k=4, sortidu=True, tile_size=8))
    off = self_join(d, SelfJoinConfig(eps=eps, k=4, sortidu=False, tile_size=8))
    assert on.stats.num_tile_pairs_evaluated < off.stats.num_tile_pairs_evaluated
    np.testing.assert_array_equal(on.counts, off.counts)


def test_shortc_skips_blocks():
    d = exponential_dataset(500, 64, seed=11)
    res = self_join(
        d, SelfJoinConfig(eps=0.1, k=6, shortc=True, tile_size=16, dim_block=8)
    )
    assert res.stats.dim_blocks_skipped > 0


def test_pairs_mode_matches_brute():
    d = exponential_dataset(250, 16, seed=12)
    eps = 0.08
    res = self_join(d, SelfJoinConfig(eps=eps, k=4, tile_size=16), return_pairs=True)
    got = set(map(tuple, res.pairs.tolist()))
    want = set(map(tuple, brute_pairs(d, eps).tolist()))
    assert got == want
    assert len(res.pairs) == res.stats.num_results


def test_pallas_backend_matches_jnp():
    d = exponential_dataset(300, 32, seed=13)
    eps = 0.1
    base = SelfJoinConfig(eps=eps, k=4, tile_size=16, dim_block=8)
    r1 = self_join(d, base)
    r2 = self_join(d, dataclasses.replace(base, use_pallas=True))
    np.testing.assert_array_equal(r1.counts, r2.counts)
    assert r1.stats.dim_blocks_skipped == r2.stats.dim_blocks_skipped


def test_ego_baseline_matches_brute():
    d = exponential_dataset(400, 16, seed=14)
    eps = 0.06
    np.testing.assert_array_equal(ego_join_counts(d, eps), brute_counts(d, eps))


def test_selectivity_definition():
    d = exponential_dataset(300, 16, seed=15)
    res = self_join(d, SelfJoinConfig(eps=0.05, k=4, tile_size=16))
    # paper Eq. 1: S_D = (|R| - |D|) / |D|
    assert res.stats.selectivity == pytest.approx(
        (res.stats.num_results - 300) / 300
    )


def test_select_k_prefers_moderate_k():
    d = exponential_dataset(2000, 16, seed=16)
    ests = estimate_k_costs(d, 0.05, ks=[1, 2, 4, 6, 8, 12])
    k = select_k(d, 0.05, ks=[1, 2, 4, 6, 8, 12])
    # paper Sec. 5.6: k > 10 degrades search cost exponentially
    assert k <= 10
    by_k = {e.k: e for e in ests}
    assert by_k[12].search_ops > by_k[6].search_ops


def test_k_cost_samples_are_independent_and_deterministic():
    d = exponential_dataset(1500, 16, seed=17)
    ks = [2, 3, 4, 6]
    a = estimate_k_costs(d, 0.05, ks)
    b = estimate_k_costs(d, 0.05, ks)
    # same seed -> identical estimates (one generator threads the whole run)
    assert [(e.k, e.total_ops) for e in a] == [(e.k, e.total_ops) for e in b]
    # per-k mu samples draw from the advancing generator stream: the same k
    # estimated twice in one run sees two DIFFERENT samples.  (The old bug
    # re-built default_rng(seed) inside the loop, so every k's mu sample was
    # the identical index sequence -- under it this assertion fails.)
    dup = estimate_k_costs(d, 0.05, [4, 4, 4])
    assert len({e.compare_ops for e in dup}) > 1


def test_select_k_ties_prefer_smaller_k_any_order():
    d = exponential_dataset(800, 16, seed=18)
    ks = [2, 3, 4, 6, 8]
    # candidate order must not matter (ties resolve to the smaller k)
    assert select_k(d, 0.05, ks) == select_k(d, 0.05, list(reversed(ks)))
    # duplicated candidates are exact ties: the duplicate never shadows
    assert select_k(d, 0.05, [4, 4, 4]) == 4


def test_empty_and_tiny_inputs():
    empty = np.zeros((0, 8), np.float32)
    res = self_join(empty, SelfJoinConfig(eps=0.1, k=2))
    assert res.counts.shape == (0,)
    one = np.random.default_rng(0).random((1, 8)).astype(np.float32)
    res = self_join(one, SelfJoinConfig(eps=0.1, k=2))
    assert res.counts.tolist() == [1]
