"""End-to-end behaviour tests for the paper's system."""
import numpy as np

from repro.core import SelfJoinConfig, self_join
from repro.core.brute import brute_counts
from repro.data import exponential_dataset
from repro.data.dedup import (
    dedup_token_dataset, find_near_duplicates, hashed_ngram_embed,
)
from repro.data.tokens import TokenPipeline


def test_full_pipeline_all_optimizations():
    """The paper's full configuration (REORDER + SORTIDU + SHORTC + k<n) on
    a worst-case exponential dataset (Sec. 5.7.2), validated end to end."""
    d = exponential_dataset(1200, 32, seed=21)
    eps = 0.08
    cfg = SelfJoinConfig(eps=eps, k=6, reorder=True, sortidu=True, shortc=True,
                         tile_size=32, dim_block=8)
    res = self_join(d, cfg)
    np.testing.assert_array_equal(res.counts, brute_counts(d, eps))
    # workload counters populated for the benchmark harness
    assert res.stats.num_nonempty_cells > 0
    assert res.stats.num_candidates >= res.stats.num_results
    assert 0 < res.stats.selectivity < 1200


def test_dedup_finds_planted_duplicates():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 1000, (40, 64))
    # plant near-duplicates: copies with a couple of token edits
    dups = base[:10].copy()
    dups[:, ::17] += 1
    examples = np.concatenate([base, dups])
    emb = hashed_ngram_embed(examples, dim=16)
    # near-dup radius: planted copies land at ~0.1-0.3, unrelated docs ~0.5+
    res = find_near_duplicates(emb, eps=0.35)
    assert res.num_duplicate_pairs >= 8          # planted pairs found
    assert len(res.keep) <= 45                    # dups collapsed
    deduped = dedup_token_dataset(examples, eps=0.35, embed_dim=16)
    assert deduped.shape[0] == len(res.keep)


def test_token_pipeline_deterministic_resume():
    p = TokenPipeline(vocab=1000, batch=4, seq=16, seed=3)
    b7 = p.batch_at(7)
    it = iter(p)
    for _ in range(7):
        next(it)
    b7b = next(it)
    np.testing.assert_array_equal(b7["tokens"], b7b["tokens"])
    assert b7["tokens"].max() < 1000
