"""Pallas flash-attention kernel vs dense softmax oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import ref_attention


def _qkv(bh, sq, sk, dh, dv, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (bh, sq, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (bh, sk, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (bh, sk, dv), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("sq,sk,qc,kc", [
    (64, 64, 16, 16), (128, 128, 32, 64), (64, 128, 64, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_dense(sq, sk, qc, kc, causal):
    if causal and sq != sk:
        pytest.skip("causal assumes aligned q/k positions")
    q, k, v = _qkv(4, sq, sk, 32, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    ref = ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_mla_shaped_dv_differs():
    # MQA/MLA shape: dv != dh
    q, k, v = _qkv(2, 64, 64, 48, 16, jnp.float32, seed=1)
    out = flash_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    ref = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_inputs():
    q, k, v = _qkv(2, 64, 64, 32, 32, jnp.bfloat16, seed=2)
    out = flash_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    ref = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_flash_custom_scale():
    q, k, v = _qkv(2, 32, 32, 24, 24, jnp.float32, seed=3)
    out = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16,
                          scale=0.125)
    ref = ref_attention(q, k, v, causal=True, scale=0.125)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
