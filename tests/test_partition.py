"""Entity partitioning and scaling simulation (paper Sec. 6.2, Figs. 10-11)."""
import numpy as np

from repro.core.partition import assign_dynamic, make_partition, simulate_scaling


def test_partition_covers_all_points():
    p = make_partition(10_000, 8, 32)
    assert p.batch_bounds[0] == 0 and p.batch_bounds[-1] == 10_000
    assert (np.diff(p.batch_bounds) >= 0).all()
    assert p.num_batches % p.num_workers == 0


def test_round_robin_balanced():
    p = make_partition(1_000, 4, 32)
    per_worker = [len(p.batches_of(w)) for w in range(4)]
    assert len(set(per_worker)) == 1  # N_b mod |p| == 0 (paper Sec. 6.2)


def test_rounding_up_to_worker_multiple():
    p = make_partition(1_000, 7, 30)
    assert p.num_batches % 7 == 0 and p.num_batches >= 30


def test_lpt_never_worse_than_round_robin():
    rng = np.random.default_rng(0)
    costs = rng.exponential(1.0, 64)
    for workers in (2, 4, 8):
        rr = max(
            costs[np.arange(64) % workers == w].sum() for w in range(workers)
        )
        lpt_assign = assign_dynamic(costs, workers)
        lpt = max(costs[lpt_assign == w].sum() for w in range(workers))
        assert lpt <= rr + 1e-9


def test_simulated_scaling_near_ideal_for_uniform_costs():
    """Paper Fig. 11: entity partitioning -> near-ideal speedup."""
    costs = np.full(128, 14.0)  # the paper's ~14 s batches (Fig. 10)
    rows = simulate_scaling(costs, [1, 2, 4, 8, 16, 32])
    for p, t, speedup in rows:
        assert speedup > 0.95 * p
