"""The benchmark regression gate (``benchmarks/compare.py``).

The gate is itself part of the execution-tier lockdown (DESIGN.md #9): a
contract drift (the cost model flipping a dispatch decision, a trace-count
change) or an order-of-magnitude wall-time regression must turn CI red.
These tests inject exactly those defects into synthetic BENCH_*.json pairs
and require a non-zero exit -- including through the real script entry
point, which is what ``make bench-compare`` gates on.
"""
import copy
import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import compare  # noqa: E402

BASE = {
    "bench": "dense",
    "contracts": {"auto_tier/dims=2": "indexed", "parity": "ok"},
    "metrics": {"dense_us/dims=2": 100.0, "indexed_us/dims=2": 200.0},
    "info": {"tiny": True},
}


def _write(d, payload):
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, "BENCH_dense.json"), "w") as f:
        json.dump(payload, f)


def _dirs(tmp_path, current_payload):
    b, c = str(tmp_path / "baseline"), str(tmp_path / "current")
    _write(b, BASE)
    _write(c, current_payload)
    return b, c


def test_identical_payloads_pass(tmp_path):
    b, c = _dirs(tmp_path, BASE)
    assert compare.compare_dirs(b, c, 8.0) == []
    assert compare.main(["--baseline", b, "--current", c]) == 0


def test_faster_metrics_and_extra_keys_pass(tmp_path):
    cur = copy.deepcopy(BASE)
    cur["metrics"]["dense_us/dims=2"] = 1.0          # faster: never a failure
    cur["metrics"]["new_us/dims=4"] = 9e9            # new rows: not gated yet
    cur["contracts"]["auto_tier/dims=4"] = "dense"
    b, c = _dirs(tmp_path, cur)
    assert compare.compare_dirs(b, c, 8.0) == []


def test_injected_walltime_regression_fails(tmp_path):
    cur = copy.deepcopy(BASE)
    cur["metrics"]["dense_us/dims=2"] = 100.0 * 20   # > 8x slack
    b, c = _dirs(tmp_path, cur)
    failures = compare.compare_dirs(b, c, 8.0)
    assert len(failures) == 1 and "regressed" in failures[0]
    # within a looser slack the same numbers pass
    assert compare.compare_dirs(b, c, 25.0) == []
    assert compare.main(["--baseline", b, "--current", c]) == 1


def test_contract_drift_fails_regardless_of_slack(tmp_path):
    cur = copy.deepcopy(BASE)
    cur["contracts"]["auto_tier/dims=2"] = "dense"   # dispatch flipped
    b, c = _dirs(tmp_path, cur)
    failures = compare.compare_dirs(b, c, 1e9)
    assert len(failures) == 1 and "changed" in failures[0]


def test_missing_rows_and_missing_files_fail(tmp_path):
    cur = copy.deepcopy(BASE)
    del cur["metrics"]["indexed_us/dims=2"]
    del cur["contracts"]["parity"]
    b, c = _dirs(tmp_path, cur)
    failures = compare.compare_dirs(b, c, 8.0)
    assert len(failures) == 2 and all("missing" in f for f in failures)
    # a baseline with no fresh counterpart at all is a failure too
    empty = str(tmp_path / "empty")
    os.makedirs(empty)
    assert any("no fresh result" in f for f in compare.compare_dirs(b, empty, 8.0))
    # and a baseline dir with no baselines means the gate is miswired
    assert compare.compare_dirs(empty, c, 8.0) != []


@pytest.mark.parametrize("inject", [False, True])
def test_script_exit_status_end_to_end(tmp_path, inject):
    """`make bench-compare`'s actual gate: the script's process exit code."""
    cur = copy.deepcopy(BASE)
    if inject:
        cur["metrics"]["indexed_us/dims=2"] = 200.0 * 50
    b, c = _dirs(tmp_path, cur)
    script = os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "compare.py"
    )
    out = subprocess.run(
        [sys.executable, script, "--baseline", b, "--current", c],
        capture_output=True, text=True,
    )
    assert (out.returncode != 0) == inject, out.stderr
    if inject:
        assert "regressed" in out.stderr


def test_committed_baselines_are_loadable():
    """The repo's own baselines parse and carry the crossover contract."""
    bdir = os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "baselines"
    )
    with open(os.path.join(bdir, "BENCH_dense.json")) as f:
        dense = json.load(f)
    assert dense["contracts"]["parity"] == "ok"
    tiers = [v for k, v in dense["contracts"].items()
             if k.startswith("auto_tier/")]
    assert "dense" in tiers and "indexed" in tiers  # a real crossover
    assert dense["info"]["auto_crossover_dims"] is not None
    with open(os.path.join(bdir, "BENCH_service.json")) as f:
        service = json.load(f)
    assert service["contracts"]["num_traces"] > 0
