"""Fused ring PAIRS mode (DESIGN.md #7b): materialized pair lists inside
the one-program distributed join.

Parity matrix: for every dataset kind in the shared correctness matrix the
fused pair SET must equal the host-driven BSP loop (``fused=False``, the
differential oracle), the single-device ``SelfJoinEngine.pairs``, and the
brute-force oracle -- exactly.  A non-overflowing join is one trace and one
device dispatch; the per-worker cursors account for every emitted pair.

The overflow protocol is exercised whitebox (shrinking the packed capacity
forces the grow-and-retry ladder mid-ring) and blackbox (a tiny explicit
``max_pairs`` raises on both the fused and host paths).  The 8-device
matrix runs in a subprocess (the device-count flag must precede jax init);
in-process tests cover the 1-device mesh.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from oracles import brute_counts, brute_pairs, brute_topk, make_dataset, pair_set
from repro.core import (
    DistributedSelfJoinEngine,
    SelfJoinConfig,
    SelfJoinEngine,
)


def _mesh1():
    import jax

    return jax.make_mesh((1,), ("data",))


def _cfg(eps, **kw):
    kw.setdefault("k", 4)
    kw.setdefault("tile_size", 16)
    return SelfJoinConfig(eps=eps, **kw)


def test_fused_pairs_parity_matrix_one_device(dataset_case):
    name, data, eps = dataset_case
    cfg = _cfg(eps)
    de = DistributedSelfJoinEngine(data, cfg, mesh=_mesh1(), fused=True)
    res = de.self_join_pairs()
    truth = pair_set(brute_pairs(data, eps))
    assert pair_set(res.pairs) == truth, name
    np.testing.assert_array_equal(res.counts, brute_counts(data, eps))
    # the two distributed paths and the single-device engine agree on the SET
    # (emission order differs: per-worker ring order vs schedule order)
    assert pair_set(de.self_join_pairs(fused=False).pairs) == truth, name
    assert pair_set(SelfJoinEngine(data, cfg).pairs().pairs) == truth, name
    # non-overflowing fused join: one trace, one dispatch, cursors exact
    assert de.fused_pairs_traces == 1, name
    assert res.stats.num_device_dispatches == 1
    assert res.stats.overflow_retries == 0
    assert sum(res.stats.worker_pair_cursors) == res.stats.num_results
    assert res.stats.num_results == len(truth)


def test_fused_pairs_warm_reuse_and_eps_sweep():
    d = make_dataset("exponential", 403, 16, seed=5)
    de = DistributedSelfJoinEngine(d, _cfg(0.06), mesh=_mesh1(), fused=True)
    first = de.self_join_pairs()
    assert de.fused_pairs_traces == 1 and de.fused_pairs_executions == 1
    # warm repeat and an eps sweep at or below the packed radius re-execute
    # the SAME compiled program: no retrace, no repack, no retry
    again = de.self_join_pairs()
    assert pair_set(again.pairs) == pair_set(first.pairs)
    small = de.self_join_pairs(eps=0.03)
    assert pair_set(small.pairs) == pair_set(brute_pairs(d, 0.03))
    assert de.fused_pairs_traces == 1 and de.fused_pairs_executions == 3
    assert again.stats.num_device_dispatches == 1
    assert small.stats.num_device_dispatches == 1


def test_fused_pairs_overflow_retry_mid_ring():
    # whitebox: shrink the packed auto capacity below |R_k| so the single
    # fused dispatch overflows; the exact fleet-max cursor is known after
    # the pass, so the ladder regrows once and the retry is exact
    d = make_dataset("clustered", 301, 8, seed=7)
    de = DistributedSelfJoinEngine(d, _cfg(0.25), mesh=_mesh1(), fused=True)
    de.count()  # builds the fused pack (capacity estimate included)
    truth = pair_set(brute_pairs(d, 0.25))
    assert len(truth) > 64
    de._fused_pack["pairs_cap"] = 64
    de._fused_pack.pop("pairs_warm", None)
    res = de.self_join_pairs()
    assert res.stats.overflow_retries >= 1
    assert res.stats.num_device_dispatches == 1 + res.stats.overflow_retries
    assert res.stats.pairs_capacity >= len(truth)
    assert pair_set(res.pairs) == truth
    # the converged (cap, hit_cap) is remembered: the next join is clean
    warm = de.self_join_pairs()
    assert warm.stats.overflow_retries == 0
    assert warm.stats.num_device_dispatches == 1


def test_explicit_max_pairs_raises_on_both_paths():
    d = make_dataset("uniform", 200, 8, seed=11)
    de = DistributedSelfJoinEngine(d, _cfg(0.3), mesh=_mesh1(), fused=True)
    total = len(brute_pairs(d, 0.3))
    assert total > 8
    with pytest.raises(RuntimeError, match="max_pairs=8"):
        de.self_join_pairs(max_pairs=8)
    with pytest.raises(RuntimeError, match="max_pairs=8"):
        de.self_join_pairs(max_pairs=8, fused=False)
    # a sufficient explicit cap succeeds on both paths
    ok = de.self_join_pairs(max_pairs=2 * total)
    assert len(ok.pairs) == total
    assert len(de.self_join_pairs(max_pairs=total, fused=False).pairs) == total


def test_eps_zero_duplicated_points():
    d = make_dataset("duplicated", 90, 6, seed=3)
    de = DistributedSelfJoinEngine(d, _cfg(0.0, k=3, tile_size=8), mesh=_mesh1(), fused=True)
    res = de.self_join_pairs()
    truth = pair_set(brute_pairs(d, 0.0))
    assert pair_set(res.pairs) == truth
    # duplicate groups make multiplicities > 1 even at radius zero
    assert len(truth) > d.shape[0]


@pytest.mark.parametrize("kind,n,dims,eps", [
    ("uniform", 1, 5, 0.1),          # single point: only the self pair
    ("constant_dims", 120, 6, 0.2),  # degenerate dimensions
])
def test_fused_pairs_edge_cases_one_device(kind, n, dims, eps):
    d = make_dataset(kind, n, dims, seed=3)
    de = DistributedSelfJoinEngine(
        d, _cfg(eps, k=3, tile_size=8, dim_block=8), mesh=_mesh1(), fused=True
    )
    assert pair_set(de.self_join_pairs().pairs) == pair_set(brute_pairs(d, eps))


def test_fused_knn_matches_brute_topk():
    d = make_dataset("clustered", 160, 8, seed=13)
    de = DistributedSelfJoinEngine(d, _cfg(0.05), mesh=_mesh1(), fused=True)
    res = de.knn(5)
    ti, td = brute_topk(d, d, 5)
    np.testing.assert_array_equal(res.indices, ti)
    np.testing.assert_allclose(res.distances, td, rtol=0, atol=0)
    assert res.eps_rounds >= 1
    # k > |D|: unreachable slots pad with -1 / +inf
    tiny = make_dataset("uniform", 3, 4, seed=2)
    tres = DistributedSelfJoinEngine(
        tiny, _cfg(0.1, k=2, tile_size=8), mesh=_mesh1(), fused=True
    ).knn(5)
    ti, td = brute_topk(tiny, tiny, 5)
    np.testing.assert_array_equal(tres.indices, ti)
    np.testing.assert_allclose(tres.distances, td, rtol=0, atol=0)


def test_knn_k_zero_and_invalid():
    d = make_dataset("uniform", 32, 4, seed=1)
    de = DistributedSelfJoinEngine(d, _cfg(0.1, k=2, tile_size=8), mesh=_mesh1(), fused=True)
    res = de.knn(0)
    assert res.indices.shape == (32, 0) and res.eps_rounds == 0
    with pytest.raises(ValueError, match=">= 0"):
        de.knn(-1)


def test_fused_true_requires_fused_engine():
    d = make_dataset("uniform", 64, 4, seed=1)
    host = DistributedSelfJoinEngine(d, _cfg(0.1, k=2), num_workers=4)
    with pytest.raises(ValueError, match="fused=True"):
        host.self_join_pairs(fused=True)
    # the host path itself works fine on the same engine
    assert pair_set(host.self_join_pairs().pairs) == pair_set(brute_pairs(d, 0.1))


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, sys.argv[1])
    sys.path.insert(0, sys.argv[2])
    import numpy as np, jax
    from oracles import DATASET_CASES, brute_pairs, brute_topk, make_dataset, pair_set
    from repro.core import DistributedSelfJoinEngine, SelfJoinConfig

    mesh = jax.make_mesh((8,), ("data",))

    # every dataset kind, fused on the real 8-ring vs the brute oracle;
    # the heaviest case additionally checks both assignments against the
    # host-driven BSP loop (the differential oracle)
    for name, data, eps in DATASET_CASES:
        cfg = SelfJoinConfig(eps=eps, k=4, tile_size=16)
        truth = pair_set(brute_pairs(data, eps))
        assignments = (
            ("round_robin", "dynamic") if name == "exp16" else ("dynamic",)
        )
        for assignment in assignments:
            eng = DistributedSelfJoinEngine(
                data, cfg, mesh=mesh, assignment=assignment, fused=True
            )
            res = eng.self_join_pairs()
            tag = f"{name}/{assignment}"
            assert pair_set(res.pairs) == truth, f"{tag}: fused != brute"
            assert eng.fused_pairs_traces == 1, f"{tag}: retraced"
            assert res.stats.num_device_dispatches == 1, tag
            assert res.stats.overflow_retries == 0, tag
            assert sum(res.stats.worker_pair_cursors) == len(truth), tag
            assert res.stats.num_workers == 8 and res.stats.num_rounds == 8
            if name == "exp16":
                host = eng.self_join_pairs(fused=False)
                assert pair_set(host.pairs) == truth, f"{tag}: host != brute"
                np.testing.assert_array_equal(res.counts, host.counts)

    # workers with zero query batches and empty shards (|D| < |p|)
    tiny = make_dataset("uniform", 5, 4, seed=4)
    tcfg = SelfJoinConfig(eps=0.3, k=2, tile_size=8)
    teng = DistributedSelfJoinEngine(tiny, tcfg, mesh=mesh, fused=True)
    tres = teng.self_join_pairs()
    ttruth = pair_set(brute_pairs(tiny, 0.3))
    assert pair_set(tres.pairs) == ttruth, "tiny: fused != brute"
    assert pair_set(teng.self_join_pairs(fused=False).pairs) == ttruth

    # eps == 0 with duplicated points, on the real ring
    dup = make_dataset("duplicated", 90, 6, seed=3)
    deng = DistributedSelfJoinEngine(
        dup, SelfJoinConfig(eps=0.0, k=3, tile_size=8), mesh=mesh, fused=True
    )
    assert pair_set(deng.self_join_pairs().pairs) == pair_set(brute_pairs(dup, 0.0))

    # explicit cap overflow raises from inside the one-program ring
    data = DATASET_CASES[0][1]
    eng = DistributedSelfJoinEngine(
        data, SelfJoinConfig(eps=DATASET_CASES[0][2], k=4, tile_size=16),
        mesh=mesh, fused=True,
    )
    try:
        eng.self_join_pairs(max_pairs=8)
    except RuntimeError as e:
        assert "max_pairs=8" in str(e)
    else:
        raise AssertionError("tiny max_pairs did not raise on the fused path")

    # distributed kNN routes through the fused pairs join and stays exact
    d = make_dataset("clustered", 160, 8, seed=13)
    kres = DistributedSelfJoinEngine(
        d, SelfJoinConfig(eps=0.05, k=4, tile_size=16), mesh=mesh, fused=True
    ).knn(5)
    ti, td = brute_topk(d, d, 5)
    assert np.array_equal(kres.indices, ti)
    assert np.array_equal(kres.distances, td)
    print("FUSED_PAIRS_OK")
    """
)


def test_fused_pairs_8_devices():
    here = os.path.dirname(__file__)
    src = os.path.join(here, "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, src, here],
        capture_output=True, text=True, timeout=600,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FUSED_PAIRS_OK" in out.stdout
