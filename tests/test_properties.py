"""Property-based tests (hypothesis) for the self-join invariants.

Skipped gracefully when hypothesis is absent (it is a dev-only dependency;
see requirements-dev.txt).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import SelfJoinConfig, self_join
from repro.core.brute import brute_counts
from repro.core.grid import adjacent_cell_pairs, build_grid, build_tile_plan
from repro.core.reorder import variance_reorder


def _data(draw, max_n=200, max_d=12):
    n = draw(st.integers(8, max_n))
    d = draw(st.integers(2, max_d))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["uniform", "exp", "clustered"]))
    if kind == "uniform":
        pts = rng.random((n, d))
    elif kind == "exp":
        pts = np.clip(rng.exponential(1 / 40.0, (n, d)), 0, 1)
    else:
        c = rng.random((4, d))
        pts = np.clip(c[rng.integers(0, 4, n)] + rng.normal(0, 0.05, (n, d)), 0, 1)
    # quantize so fp32 distance sums are exact in every formulation
    return (np.round(pts * 64) / 64).astype(np.float32)


points = st.builds(lambda: None)  # placeholder (built in @given via draw)


@st.composite
def dataset(draw):
    return _data(draw)


@settings(max_examples=25, deadline=None)
@given(dataset(), st.sampled_from([0.05, 0.11, 0.23, 0.41]), st.integers(1, 6))
def test_join_equals_brute(d, eps, k):
    cfg = SelfJoinConfig(eps=eps, k=k, tile_size=8, dim_block=8)
    res = self_join(d, cfg)
    np.testing.assert_array_equal(res.counts, brute_counts(d, eps))


@settings(max_examples=15, deadline=None)
@given(dataset(), st.integers(0, 2**31 - 1))
def test_reorder_preserves_pairwise_distances(d, seed):
    r, perm = variance_reorder(d, 0.05, seed % 1000)
    assert sorted(perm.tolist()) == list(range(d.shape[1]))
    i, j = 0, min(5, d.shape[0] - 1)
    dd = np.linalg.norm(d[i] - d[j])
    rr = np.linalg.norm(r[i] - r[j])
    assert abs(dd - rr) < 1e-5


@settings(max_examples=15, deadline=None)
@given(dataset())
def test_counts_monotone_in_eps(d):
    c1 = self_join(d, SelfJoinConfig(eps=0.1, k=3, tile_size=8)).counts
    c2 = self_join(d, SelfJoinConfig(eps=0.2, k=3, tile_size=8)).counts
    assert (c2 >= c1).all()


@settings(max_examples=15, deadline=None)
@given(dataset(), st.sampled_from([0.1, 0.25]))
def test_grid_invariants(d, eps):
    grid = build_grid(d, eps, k=3)
    # every point appears exactly once in the sorted layout
    assert sorted(grid.point_order.tolist()) == list(range(d.shape[0]))
    assert int(grid.cell_count.sum()) == d.shape[0]
    # adjacency is symmetric and includes self-pairs
    ca, cb = adjacent_cell_pairs(grid)
    pairs = set(zip(ca.tolist(), cb.tolist()))
    assert all((b, a) in pairs for a, b in pairs)
    assert all((c, c) in pairs for c in range(grid.num_cells))
    # tile plan covers each cell's points exactly once
    plan = build_tile_plan(grid, 8, sortidu=False)
    covered = np.zeros(d.shape[0], bool)
    for s, l in zip(plan.tile_start, plan.tile_len):
        assert not covered[s : s + l].any()
        covered[s : s + l] = True
    assert covered.all()


@settings(max_examples=10, deadline=None)
@given(dataset())
def test_self_pairs_always_included(d):
    res = self_join(d, SelfJoinConfig(eps=0.01, k=3, tile_size=8))
    assert (res.counts >= 1).all()  # every point finds at least itself
