"""Property-based tests (hypothesis) for the self-join invariants.

Skipped gracefully when hypothesis is absent (it is a dev-only dependency;
see requirements-dev.txt).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from oracles import brute_counts, brute_pairs, brute_topk
from repro.core import SelfJoinConfig, SelfJoinEngine, self_join
from repro.core import batching
from repro.core.grid import adjacent_cell_pairs, build_grid, build_tile_plan
from repro.core.reorder import apply_reorder, inverse_perm, variance_reorder
from repro.join import QueryService, SimilarityIndex
from repro.kernels.ref import direct_sqdist, matmul_sqdist


def _data(draw, max_n=200, max_d=12):
    n = draw(st.integers(8, max_n))
    d = draw(st.integers(2, max_d))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["uniform", "exp", "clustered"]))
    if kind == "uniform":
        pts = rng.random((n, d))
    elif kind == "exp":
        pts = np.clip(rng.exponential(1 / 40.0, (n, d)), 0, 1)
    else:
        c = rng.random((4, d))
        pts = np.clip(c[rng.integers(0, 4, n)] + rng.normal(0, 0.05, (n, d)), 0, 1)
    # quantize so fp32 distance sums are exact in every formulation
    return (np.round(pts * 64) / 64).astype(np.float32)


points = st.builds(lambda: None)  # placeholder (built in @given via draw)


@st.composite
def dataset(draw):
    return _data(draw)


@settings(max_examples=25, deadline=None)
@given(dataset(), st.sampled_from([0.05, 0.11, 0.23, 0.41]), st.integers(1, 6))
def test_join_equals_brute(d, eps, k):
    cfg = SelfJoinConfig(eps=eps, k=k, tile_size=8, dim_block=8)
    res = self_join(d, cfg)
    np.testing.assert_array_equal(res.counts, brute_counts(d, eps))


@settings(max_examples=15, deadline=None)
@given(dataset(), st.integers(0, 2**31 - 1))
def test_reorder_preserves_pairwise_distances(d, seed):
    r, perm = variance_reorder(d, 0.05, seed % 1000)
    assert sorted(perm.tolist()) == list(range(d.shape[1]))
    i, j = 0, min(5, d.shape[0] - 1)
    dd = np.linalg.norm(d[i] - d[j])
    rr = np.linalg.norm(r[i] - r[j])
    assert abs(dd - rr) < 1e-5


@settings(max_examples=15, deadline=None)
@given(dataset(), st.integers(0, 2**31 - 1))
def test_apply_reorder_roundtrips_external_points(d, seed):
    """External points permute identically to the dataset, and invert back.

    The serving contract: ``variance_reorder``'s output IS ``apply_reorder``
    of its permutation, queries permuted with the persisted perm land in the
    index's frame, and ``inverse_perm`` undoes it exactly.
    """
    r, perm = variance_reorder(d, 0.05, seed % 1000)
    np.testing.assert_array_equal(r, apply_reorder(d, perm))
    external = d[:: max(1, d.shape[0] // 7)] + np.float32(1 / 64)
    round_trip = apply_reorder(apply_reorder(external, perm), inverse_perm(perm))
    np.testing.assert_array_equal(round_trip, external)
    inv = inverse_perm(perm)
    np.testing.assert_array_equal(perm[inv], np.arange(d.shape[1]))
    np.testing.assert_array_equal(inv[perm], np.arange(d.shape[1]))


@settings(max_examples=10, deadline=None)
@given(dataset(), st.integers(1, 9))
def test_knn_equals_bruteforce_topk(d, k):
    """Service kNN == float64 brute-force top-k, ties by data id, any data."""
    svc = QueryService(
        SimilarityIndex(d, SelfJoinConfig(eps=0.2, k=3, tile_size=8, dim_block=8))
    )
    q = d[: min(16, d.shape[0])]
    res = svc.knn(q, k)
    want_idx, want_dist = brute_topk(q, d, k)
    np.testing.assert_array_equal(res.indices, want_idx)
    np.testing.assert_array_equal(res.distances, want_dist)


@settings(max_examples=15, deadline=None)
@given(dataset())
def test_counts_monotone_in_eps(d):
    c1 = self_join(d, SelfJoinConfig(eps=0.1, k=3, tile_size=8)).counts
    c2 = self_join(d, SelfJoinConfig(eps=0.2, k=3, tile_size=8)).counts
    assert (c2 >= c1).all()


@settings(max_examples=15, deadline=None)
@given(dataset(), st.sampled_from([0.1, 0.25]))
def test_grid_invariants(d, eps):
    grid = build_grid(d, eps, k=3)
    # every point appears exactly once in the sorted layout
    assert sorted(grid.point_order.tolist()) == list(range(d.shape[0]))
    assert int(grid.cell_count.sum()) == d.shape[0]
    # adjacency is symmetric and includes self-pairs
    ca, cb = adjacent_cell_pairs(grid)
    pairs = set(zip(ca.tolist(), cb.tolist()))
    assert all((b, a) in pairs for a, b in pairs)
    assert all((c, c) in pairs for c in range(grid.num_cells))
    # tile plan covers each cell's points exactly once
    plan = build_tile_plan(grid, 8, sortidu=False)
    covered = np.zeros(d.shape[0], bool)
    for s, l in zip(plan.tile_start, plan.tile_len):
        assert not covered[s : s + l].any()
        covered[s : s + l] = True
    assert covered.all()


@settings(max_examples=10, deadline=None)
@given(dataset())
def test_self_pairs_always_included(d):
    res = self_join(d, SelfJoinConfig(eps=0.01, k=3, tile_size=8))
    assert (res.counts >= 1).all()  # every point finds at least itself


@settings(max_examples=20, deadline=None)
@given(dataset(), st.sampled_from([0.1, 0.25]))
def test_grid_cell_assignment_roundtrips_point_order(d, eps):
    """pts_sorted IS D[point_order], and each point lies in its owning cell."""
    grid = build_grid(d, eps, k=3)
    np.testing.assert_array_equal(grid.pts_sorted, d[grid.point_order])
    # recomputing each sorted point's cell coords (same floor rule as
    # build_grid) must land on its owning cell's stored coordinates
    coords = (
        np.floor(
            grid.pts_sorted[:, : grid.k].astype(np.float64) / grid.bin_width
        ).astype(np.int64)
        - grid.origin[None, :]
    )
    cell_of_sorted = np.repeat(
        np.arange(grid.num_cells, dtype=np.int64), grid.cell_count
    )
    np.testing.assert_array_equal(coords, grid.cell_coords[cell_of_sorted])
    # and cell runs tile the sorted layout contiguously
    starts = np.concatenate([[0], np.cumsum(grid.cell_count)[:-1]])
    np.testing.assert_array_equal(grid.cell_start, starts)


@settings(max_examples=20, deadline=None)
@given(dataset(), st.sampled_from([0.05, 0.11, 0.23]))
def test_sortidu_plan_covers_all_true_pairs(d, eps):
    """The SORTIDU-pruned tile-pair plan is a superset of all true <=eps pairs."""
    grid = build_grid(d, eps, k=3)
    plan = build_tile_plan(grid, 8, sortidu=True)
    tile_of_pos = np.empty(d.shape[0], np.int64)
    for ti, (s, l) in enumerate(zip(plan.tile_start, plan.tile_len)):
        tile_of_pos[s : s + l] = ti
    pos_of_point = np.empty(d.shape[0], np.int64)
    pos_of_point[grid.point_order] = np.arange(d.shape[0])
    plan_pairs = set(zip(plan.pair_a.tolist(), plan.pair_b.tolist()))
    for a, b in brute_pairs(d, eps):
        ta = int(tile_of_pos[pos_of_point[a]])
        tb = int(tile_of_pos[pos_of_point[b]])
        assert (ta, tb) in plan_pairs, f"true pair {(a, b)} pruned"


@settings(max_examples=10, deadline=None)
@given(dataset(), st.sampled_from([0.1, 0.25]))
def test_capacity_estimate_never_underallocates(d, eps):
    """A full-sample size estimate (and its capacity) covers the true |R|."""
    cfg = SelfJoinConfig(eps=eps, k=3, tile_size=8, dim_block=8)
    eng = SelfJoinEngine(d, cfg)
    est = batching.estimate_result_size(
        np.asarray(eng._tiles), np.asarray(eng._tile_len), eng.plan,
        eps=eps, dim_block=8, backend="jnp", sample_frac=1.0,
    )
    true_r = int(brute_counts(d, eps).sum())
    assert est >= true_r
    assert batching.suggest_pairs_capacity(est, 1.0) >= true_r
    res = eng.pairs()  # auto-sized buffer must end up fitting exactly |R|
    assert res.stats.pairs_capacity >= res.stats.num_results == true_r


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10**9), st.floats(0.0, 4.0))
def test_suggest_capacity_never_below_estimate(est, headroom):
    assert batching.suggest_pairs_capacity(est, headroom) >= est


@st.composite
def raw_point_sets(draw):
    """Un-quantized fp32 point sets for the matmul-identity property.

    Deliberately NOT pushed through the 1/64 quantizer: the dense tier's
    ``||a-b||^2 = |a|^2 + |b|^2 - 2 a.b`` identity is where fp32 rounding
    actually bites (catastrophic cancellation near zero), so the property
    must hold on arbitrary floats, not just the exact-friendly grid.  The
    two adversarial shapes are drawn explicitly: duplicated points (true
    distance exactly 0 -- the identity's worst cancellation case) and
    constant dimensions (zero-variance axes contribute |a|^2 + |b|^2 mass
    but no separation).
    """
    n = draw(st.integers(2, 48))
    dims = draw(st.integers(1, 24))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    scale = draw(st.sampled_from([1.0, 17.0]))
    pts = (rng.random((n, dims)) * scale).astype(np.float32)
    variant = draw(st.sampled_from(["plain", "duplicated", "constant_dims"]))
    if variant == "duplicated":
        src = rng.integers(0, n, n // 2 + 1)
        dst = rng.integers(0, n, n // 2 + 1)
        pts[dst] = pts[src]
    elif variant == "constant_dims":
        const_cols = rng.integers(0, dims, dims // 2 + 1)
        pts[:, const_cols] = pts[0, const_cols]
    m = draw(st.integers(1, n))
    return pts[:m], pts[rng.permutation(n)]


@settings(max_examples=60, deadline=None)
@given(raw_point_sets())
def test_matmul_identity_clamped_and_close_to_direct(ab):
    """The dense kernel's clamped matmul identity (DESIGN.md #9): never
    negative, exactly zero on duplicated rows' own pairing, and within
    fp32 tolerance of the direct ``sum((a-b)^2)`` form on arbitrary data."""
    a, b = ab
    got = np.asarray(matmul_sqdist(a, b))
    want = np.asarray(direct_sqdist(a, b))
    assert got.shape == (a.shape[0], b.shape[0])
    assert (got >= 0.0).all()
    # fp32 relative tolerance, absolute floor scaled by the norm products
    # that feed the identity (cancellation error is relative to those)
    floor = 1e-5 * float(
        np.maximum(np.square(a).sum(1).max(), np.square(b).sum(1).max()) + 1.0
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=floor)
    # duplicated rows across the two sides: the direct form is exactly 0
    # there, and clamping must pin the identity's negative dust to 0 too
    eq = (a[:, None, :] == b[None, :, :]).all(-1)
    assert (got[eq] <= floor).all()


@settings(max_examples=20, deadline=None)
@given(dataset(), st.sampled_from([0.07, 0.19]))
def test_dense_tier_join_equals_brute(d, eps):
    """Forced-dense execution is oracle-exact on quantized data, any kind."""
    cfg = SelfJoinConfig(eps=eps, k=3, tile_size=8, dim_block=8,
                         execution="dense")
    res = self_join(d, cfg)
    assert res.stats.execution == "dense"
    np.testing.assert_array_equal(res.counts, brute_counts(d, eps))
