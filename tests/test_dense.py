"""Hybrid dense/indexed execution (DESIGN.md #9): forced-tier differential
matrix plus the cost-model dispatch contract.

The lockdown strategy: for every dataset kind in the shared matrix, the
dense tier, the indexed tier and the float64 oracles must agree EXACTLY on
counts, pairs, and kNN (coordinates are 1/64-quantized, so both distance
formulations -- direct and clamped matmul identity -- are exact and results
compare with ``==``).  ``execution="auto"`` must then pick exactly the tier
its own recorded cost estimates say is cheaper, on the self-join and the
serving paths alike.  The whole file runs identically under
``REPRO_TEST_DEVICES=8`` (CI's multi-device leg), where the distributed
differential case exercises per-shard dispatch on 8 simulated devices.
"""
import dataclasses

import numpy as np
import pytest

from oracles import (
    bipartite_counts,
    brute_counts,
    brute_topk,
    make_dataset,
    pair_set,
)
from repro.core import (
    DistributedSelfJoinEngine,
    SelfJoinConfig,
    SelfJoinEngine,
    decide,
    dense_join_cost,
    indexed_join_cost,
    make_dense_plan,
)
from repro.join import QueryService, SimilarityIndex

MODES = ("indexed", "dense", "auto")


def _cfg(eps, **kw):
    kw.setdefault("k", 6)
    kw.setdefault("tile_size", 16)
    kw.setdefault("dim_block", 8)
    return SelfJoinConfig(eps=eps, **kw)


def _queries(d, seed, n_extra=20):
    extra = make_dataset("uniform", n_extra, d.shape[1], seed=seed)
    return np.concatenate([d[: min(33, len(d))], extra])


# -- cost model unit behaviour ------------------------------------------------


def test_cost_model_arithmetic():
    # dense: ceil(100/16) * ceil(100/16) * 16*16*8 lane ops + 100*100 epilogue
    assert dense_join_cost(100, 100, 16, 8) == 7 * 7 * 16 * 16 * 8 + 100 * 100
    assert dense_join_cost(0, 50, 16, 8) == 0.0
    # indexed: pairs * T^2 * n_pad + candidates epilogue
    assert indexed_join_cost(10, 500, 16, 8) == 10 * 16 * 16 * 8 + 500


def test_decide_modes_and_ties():
    assert decide(10.0, 5.0).execution == "dense"
    assert decide(5.0, 10.0).execution == "indexed"
    assert decide(7.0, 7.0).execution == "indexed"  # ties -> the paper's path
    for forced in ("indexed", "dense"):
        dec = decide(1.0, 2.0, forced)
        assert dec.execution == forced and dec.forced
        # forced decisions still carry both estimates for the stats record
        assert (dec.cost_indexed, dec.cost_dense) == (1.0, 2.0)
    with pytest.raises(ValueError):
        decide(1.0, 2.0, "gpu")


def test_execution_config_validates():
    with pytest.raises(ValueError):
        SelfJoinConfig(eps=0.1, execution="fast")
    assert SelfJoinConfig(eps=0.1).execution == "indexed"


def test_dense_plan_covers_all_points_in_full_tiles():
    plan = make_dense_plan(37, 8)
    assert plan.num_tiles == 5
    assert plan.tile_len.tolist() == [8, 8, 8, 8, 5]
    assert plan.num_pairs == 25 and plan.num_tile_pairs_total == 25
    assert plan.num_candidates == 37 * 37
    # tiles partition [0, 37) exactly once
    covered = np.zeros(37, bool)
    for s, l in zip(plan.tile_start, plan.tile_len):
        assert not covered[s : s + l].any()
        covered[s : s + l] = True
    assert covered.all()
    empty = make_dense_plan(0, 8)
    assert empty.num_tiles == 0 and empty.num_pairs == 0


# -- the forced-tier differential matrix -------------------------------------


def test_forced_tier_counts_and_pairs_match_oracles(dataset_case):
    name, d, eps = dataset_case
    want_counts = brute_counts(d, eps)
    results = {}
    for mode in MODES:
        eng = SelfJoinEngine(d, _cfg(eps, execution=mode))
        rc = eng.count()
        rp = eng.pairs()
        np.testing.assert_array_equal(rc.counts, want_counts)
        np.testing.assert_array_equal(rp.counts, want_counts)
        assert rc.stats.execution in ("indexed", "dense")
        if mode != "auto":
            assert rc.stats.execution == mode
        results[mode] = pair_set(rp.pairs)
    assert results["indexed"] == results["dense"] == results["auto"]


def test_forced_tier_bipartite_and_knn_match_oracles(dataset_case):
    name, d, eps = dataset_case
    q = _queries(d, seed=71)
    want_counts = bipartite_counts(q, d, eps)
    want_idx, want_dist = brute_topk(q, d, 4)
    for mode in MODES:
        idx = SimilarityIndex(d, _cfg(eps, execution=mode))
        rq = idx.engine.count_query(q, eps)
        np.testing.assert_array_equal(rq.counts, want_counts)
        if mode != "auto":
            assert rq.stats.execution == mode
        svc = QueryService(idx)
        np.testing.assert_array_equal(
            svc.range_count(q, eps).counts, want_counts
        )
        kn = svc.knn(q, 4)
        np.testing.assert_array_equal(kn.indices, want_idx)
        np.testing.assert_array_equal(kn.distances, want_dist)


def test_forced_tier_distributed_parity(dataset_case):
    """Per-shard dispatch: the distributed tier agrees across forced modes.

    Under ``REPRO_TEST_DEVICES=8`` this runs on 8 simulated devices (the
    host-driven distributed engine's worker count follows the shard count,
    not the device count, so the case is meaningful on both CI legs).
    """
    name, d, eps = dataset_case
    want = brute_counts(d, eps)
    for mode in MODES:
        de = DistributedSelfJoinEngine(
            d, _cfg(eps, execution=mode), num_workers=4
        )
        np.testing.assert_array_equal(de.count().counts, want)


# -- the auto-dispatch contract ----------------------------------------------


def test_auto_dispatch_matches_recorded_costs(dataset_case):
    name, d, eps = dataset_case
    eng = SelfJoinEngine(d, _cfg(eps, execution="auto"))
    stats = eng.count().stats
    assert stats.cost_indexed > 0 and stats.cost_dense > 0
    want_tier = "dense" if stats.cost_dense < stats.cost_indexed else "indexed"
    assert stats.execution == want_tier
    # pairs mode makes the same decision from the same index
    assert eng.pairs().stats.execution == want_tier
    # and the decision is reproducible from the public cost API
    dec = eng.resolve_execution(eps)
    assert (dec.execution, dec.cost_indexed, dec.cost_dense) == (
        stats.execution, stats.cost_indexed, stats.cost_dense,
    )


def test_auto_picks_dense_on_high_dimensional_case():
    """The grid loses filtering power in high dims (ratio -> 1): the model
    must route at least the 32-dim matrix case to the dense tier, and the
    decision must be recorded in the join stats."""
    d = make_dataset("clustered", 403, 32, seed=22)  # == clustered32 case
    eng = SelfJoinEngine(d, _cfg(0.25, execution="auto"))
    res = eng.count()
    assert res.stats.execution == "dense"
    assert res.stats.cost_dense < res.stats.cost_indexed
    np.testing.assert_array_equal(res.counts, brute_counts(d, 0.25))


def test_auto_picks_indexed_when_filtering_wins():
    """A compact low-dim case keeps the indexed tier (ties go there too)."""
    d = make_dataset("duplicated", 151, 6, seed=24)  # == duplicated6 case
    eng = SelfJoinEngine(d, _cfg(0.1, execution="auto"))
    res = eng.count()
    assert res.stats.execution == "indexed"
    assert res.stats.cost_indexed <= res.stats.cost_dense
    np.testing.assert_array_equal(res.counts, brute_counts(d, 0.1))


def test_bipartite_auto_decision_recorded_in_tables():
    d = make_dataset("exponential", 301, 16, seed=72)
    idx = SimilarityIndex(d, _cfg(0.06, execution="auto"))
    q = _queries(d, seed=73)
    tab = idx.prepare_query(q, 0.06)
    want = "dense" if tab.cost_dense < tab.cost_indexed else "indexed"
    assert tab.execution == want
    stats = idx.engine.count_query(q, 0.06).stats
    assert stats.execution == tab.execution
    assert (stats.cost_indexed, stats.cost_dense) == (
        tab.cost_indexed, tab.cost_dense,
    )


# -- the dense Pallas kernel itself ------------------------------------------


def test_dense_pallas_kernel_matches_jnp_twin():
    """The Pallas dense kernel (interpret mode) == its XLA twin == oracle,
    through the engine end to end (small chunks keep interpret mode fast)."""
    from repro.core.types import EngineConfig

    d = make_dataset("exponential", 101, 12, seed=74)
    eng_cfg = EngineConfig(count_chunk=32, pairs_chunk=16)
    base = _cfg(0.08, tile_size=8, execution="dense")
    jnp_eng = SelfJoinEngine(d, base, eng_cfg)
    pal_eng = SelfJoinEngine(
        d, dataclasses.replace(base, use_pallas=True), eng_cfg
    )
    want = brute_counts(d, 0.08)
    np.testing.assert_array_equal(jnp_eng.count().counts, want)
    np.testing.assert_array_equal(pal_eng.count().counts, want)
    assert pair_set(pal_eng.pairs().pairs) == pair_set(jnp_eng.pairs().pairs)


def test_dense_tier_eps_zero_duplicate_join():
    """eps == 0 through the clamped matmul identity: exact-duplicate and
    self matches survive (quantized coords make the identity exact)."""
    d = make_dataset("duplicated", 90, 6, seed=75)
    for mode in ("dense", "auto"):
        res = SelfJoinEngine(d, _cfg(0.0, execution=mode)).count()
        np.testing.assert_array_equal(res.counts, brute_counts(d, 0.0))
        assert (res.counts >= 1).all()
        assert res.counts.max() >= 3
