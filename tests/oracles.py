"""Shared test-oracle layer (one copy, replacing per-file duplicates).

The canonical brute-force oracles live in ``repro.core.brute`` (they are
library code the benchmarks use too); this module adds what only tests
need -- the bipartite oracle, the pair-set normalizer, and one
parameterized dataset generator whose cases cover the regimes every tier
of the join must survive:

  * uniform / exponential / clustered point distributions,
  * duplicated points (counts > 1 at eps == 0),
  * degenerate constant dimensions (zero-variance axes; REORDER must not
    divide by zero, the grid must not collapse),
  * non-divisible |D| (uneven shards / tail tiles everywhere).

Coordinates are 1/64-quantized so fp32 matmul-form distances are exact in
every formulation (DESIGN.md #6) -- tests compare counts with ``==``, never
with tolerances.
"""
from __future__ import annotations

import numpy as np

from repro.core.brute import brute_counts, brute_pairs  # noqa: F401  (re-export)
from repro.data import clustered_dataset, exponential_dataset, uniform_dataset


def quantize(pts: np.ndarray, steps: int = 64) -> np.ndarray:
    """Snap coordinates to a 1/steps lattice (exact fp32 distance sums)."""
    return (np.round(np.asarray(pts, np.float64) * steps) / steps).astype(
        np.float32
    )


def bipartite_counts(q: np.ndarray, d: np.ndarray, eps: float) -> np.ndarray:
    """Per-query counts of d-points within eps, float64 ground truth."""
    q64 = np.asarray(q, np.float64)
    d64 = np.asarray(d, np.float64)
    eps2 = np.float64(eps) ** 2
    counts = np.zeros(q64.shape[0], dtype=np.int64)
    for i0 in range(0, q64.shape[0], 512):
        a = q64[i0 : i0 + 512]
        d2 = ((a[:, None, :] - d64[None, :, :]) ** 2).sum(-1)
        counts[i0 : i0 + 512] = (d2 <= eps2).sum(1)
    return counts


def pair_set(pairs) -> set:
    """Order-insensitive comparison form of an (R, 2) pair array."""
    return set(map(tuple, np.asarray(pairs).tolist()))


def brute_topk(q: np.ndarray, d: np.ndarray, k: int):
    """Exact kNN ground truth: (indices, distances), both (|q|, k).

    Float64 distances; ties broken by data index (ascending); queries with
    fewer than k reachable points (k > |D|) pad with -1 / +inf -- the
    serving tier's kNN contract (``repro.join.QueryService.knn``).
    """
    q64 = np.asarray(q, np.float64)
    d64 = np.asarray(d, np.float64)
    nq, nd = q64.shape[0], d64.shape[0]
    indices = np.full((nq, k), -1, np.int64)
    distances = np.full((nq, k), np.inf, np.float64)
    if nd == 0 or k == 0:
        return indices, distances
    ids = np.arange(nd)
    for i in range(nq):
        dist = np.sqrt(((q64[i] - d64) ** 2).sum(axis=1))
        order = np.lexsort((ids, dist))[: min(k, nd)]
        indices[i, : order.shape[0]] = order
        distances[i, : order.shape[0]] = dist[order]
    return indices, distances


class ChurnOracle:
    """Brute-force mirror of the MUTABLE ``SimilarityIndex`` (DESIGN.md #10).

    Tracks the live set under the same global-id contract as the index:
    the seed dataset takes ids 0..n-1, ``insert`` allocates new ids upward,
    ids are never recycled, and deleting an unknown or already-deleted id
    raises ``KeyError``.  Queries answer over the live set only, with pair
    and kNN results carrying GLOBAL ids.  The live set is kept sorted by
    global id so ``brute_topk``'s tie-by-row-index equals the service's
    tie-by-global-id.
    """

    def __init__(self, pts: np.ndarray):
        pts = np.asarray(pts, np.float32)
        self.live_ids = np.arange(pts.shape[0], dtype=np.int64)
        self.live_pts = pts.copy()
        self.next_id = pts.shape[0]

    @property
    def live_count(self) -> int:
        return self.live_ids.shape[0]

    def insert(self, pts: np.ndarray) -> np.ndarray:
        pts = np.asarray(pts, np.float32)
        ids = np.arange(self.next_id, self.next_id + pts.shape[0], dtype=np.int64)
        self.next_id += pts.shape[0]
        # new ids are the largest, so appending keeps the id-sorted order
        self.live_ids = np.concatenate([self.live_ids, ids])
        self.live_pts = np.concatenate([self.live_pts, pts])
        return ids

    def delete(self, ids) -> int:
        ids = np.unique(np.asarray(ids, np.int64))
        hit = np.isin(self.live_ids, ids)
        if int(hit.sum()) != ids.shape[0]:
            bad = ids[~np.isin(ids, self.live_ids)]
            raise KeyError(f"cannot delete unknown or already-deleted ids {bad.tolist()}")
        self.live_ids = self.live_ids[~hit]
        self.live_pts = self.live_pts[~hit]
        return int(ids.shape[0])

    def range_count(self, q: np.ndarray, eps: float) -> np.ndarray:
        return bipartite_counts(q, self.live_pts, eps)

    def range_pairs(self, q: np.ndarray, eps: float) -> np.ndarray:
        """(R, 2) int64 (query row, global id), lexsorted like the service."""
        q64 = np.asarray(q, np.float64)
        d64 = np.asarray(self.live_pts, np.float64)
        d2 = ((q64[:, None, :] - d64[None, :, :]) ** 2).sum(-1)
        qr, dr = np.nonzero(d2 <= np.float64(eps) ** 2)
        pairs = np.column_stack([qr.astype(np.int64), self.live_ids[dr]])
        srt = np.lexsort((pairs[:, 1], pairs[:, 0]))
        return np.ascontiguousarray(pairs[srt])

    def topk(self, q: np.ndarray, k: int):
        """Exact kNN over the live set; indices are GLOBAL ids (-1 padded)."""
        rows, distances = brute_topk(q, self.live_pts, k)
        indices = np.where(rows >= 0, self.live_ids[np.clip(rows, 0, None)], -1)
        return indices, distances


def make_dataset(kind: str, n: int, dims: int, seed: int = 0) -> np.ndarray:
    """One generator for every distribution the test matrix exercises."""
    if kind == "uniform":
        return quantize(uniform_dataset(n, dims, seed=seed))
    if kind == "exponential":
        return quantize(exponential_dataset(n, dims, seed=seed))
    if kind == "clustered":
        return quantize(clustered_dataset(n, dims, cluster_std=0.05, seed=seed))
    if kind == "duplicated":
        # ~n points built by tiling a base set: duplicate groups of 3 plus a
        # partial group, so multiplicities differ across points
        base = quantize(uniform_dataset(max(n // 3, 1), dims, seed=seed))
        d = np.concatenate([base, base, base, base[: max(n - 3 * len(base), 0)]])
        return d[:n] if len(d) >= n else d
    if kind == "constant_dims":
        # first half of the dimensions are exactly constant (zero variance)
        d = quantize(uniform_dataset(n, dims, seed=seed))
        d[:, : max(dims // 2, 1)] = 0.5
        return d
    raise ValueError(f"unknown dataset kind {kind!r}")


# The shared correctness matrix: (name, data, eps).  Sizes are non-divisible
# by common worker/tile counts on purpose.
DATASET_CASES = [
    ("exp16", make_dataset("exponential", 501, 16, seed=21), 0.06),
    ("clustered32", make_dataset("clustered", 403, 32, seed=22), 0.25),
    ("uniform8", make_dataset("uniform", 397, 8, seed=23), 0.3),
    ("duplicated6", make_dataset("duplicated", 151, 6, seed=24), 0.1),
    ("constantdims8", make_dataset("constant_dims", 205, 8, seed=25), 0.2),
]

DATASET_IDS = [c[0] for c in DATASET_CASES]
