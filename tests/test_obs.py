"""Observability layer (DESIGN.md #11): tracer, registry, report, parity.

The load-bearing contracts:

  * disabled tracer records NOTHING and the production paths run untraced
    (one attribute check -- no spans, no registry writes);
  * under ``obs.capture()`` the span counts are EXACT mirrors of the
    engine/service counters: one "dispatch" span per
    ``num_device_dispatches`` increment, one "trace" instant per
    ``ServiceStats.num_traces`` increment, and the metrics registry deltas
    equal the stats objects (filtered by the ``path`` label -- the host
    ring mirrors at both "engine" and "ring_host", by design);
  * a capture round-trips through the Chrome-trace exporter and the
    ``repro.obs.report`` loader; malformed traces fail loudly (the CI gate).

The 8-device matrix runs in a subprocess (the device-count flag must
precede jax init), mirroring test_fused_pairs.py.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from oracles import brute_counts, brute_pairs, make_dataset, pair_set
from repro import obs
from repro.core import (
    DistributedSelfJoinEngine,
    SelfJoinConfig,
    SelfJoinEngine,
)
from repro.join import QueryService, SimilarityIndex
from repro.obs import report as obs_report
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import _NOOP, _state


def _mesh1():
    import jax

    return jax.make_mesh((1,), ("data",))


# -- tracer unit tests -------------------------------------------------------

def test_disabled_tracer_records_zero_events():
    assert not obs.enabled()
    with obs.span("work", "test", k=1) as sp:
        sp.set(extra=2)
    obs.event("tick", "test")
    obs.inc("never_total")
    obs.observe("never_hist", 1.0)
    obs.set_gauge("never_gauge", 1.0)
    assert obs.event_count() == 0
    assert obs.events() == []
    # the disabled span is the shared no-op singleton: no allocation per call
    assert obs.span("again") is _NOOP
    # nothing leaked into the registry
    assert obs.metric_value(obs.REGISTRY.snapshot(), "never_total") == 0.0


def test_disabled_join_runs_untraced(dataset_case):
    name, data, eps = dataset_case
    eng = SelfJoinEngine(data, SelfJoinConfig(eps=eps, k=4, tile_size=16))
    res = eng.pairs()
    assert obs.event_count() == 0, name
    assert pair_set(res.pairs) == pair_set(brute_pairs(data, eps)), name


def test_ring_buffer_bounds_and_drop_counter():
    obs.enable(capacity=4)
    try:
        for i in range(10):
            obs.event(f"e{i}", "test")
        evts = obs.events()
        assert [e.name for e in evts] == ["e6", "e7", "e8", "e9"]
        assert obs.dropped_count() == 6
        assert obs.event_count() == 4
    finally:
        obs.disable()
        obs.clear()


def test_span_nesting_depth_and_attrs():
    with obs.capture() as cap:
        with obs.span("outer", "test", a=1):
            with obs.span("inner", "test") as sp:
                sp.set(b=np.int64(2))  # numpy scalars must serialize
    outer = cap.spans("outer")[0]
    inner = cap.spans("inner")[0]
    assert outer.depth == 0 and inner.depth == 1
    assert outer.attrs["a"] == 1
    assert inner.attrs["b"] == 2
    assert inner.ts_us >= outer.ts_us
    assert inner.dur_us <= outer.dur_us
    json.dumps(cap.chrome_trace())  # attrs are JSON-clean


def test_capture_restores_prior_state():
    assert not obs.enabled()
    with obs.capture() as cap:
        assert obs.enabled()
        obs.event("in_cap", "test")
    assert not obs.enabled()
    assert obs.event_count() == 0  # capture cleared its buffer
    assert cap.span_count("in_cap") == 1
    # a capture inside an enable() window re-opens the window on exit
    obs.enable()
    try:
        obs.event("before", "test")
        with obs.capture() as inner:
            obs.event("inside", "test")
        assert inner.span_count("inside") == 1
        assert inner.span_count("before") == 0  # fresh buffer per capture
        assert obs.enabled()
    finally:
        obs.disable()
        obs.clear()


def test_capture_exception_still_collects():
    with pytest.raises(RuntimeError, match="boom"):
        with obs.capture() as cap:
            obs.event("pre_fail", "test")
            raise RuntimeError("boom")
    assert not obs.enabled()
    assert cap.span_count("pre_fail") == 1


# -- metrics registry --------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc(2, kind="a")
    c.inc(3, kind="b")
    g = reg.gauge("depth", "queue depth")
    g.set(5)
    g.inc(2)
    g.dec(3)
    h = reg.histogram("lat", "latency", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    snap = reg.snapshot()
    assert obs.metric_value(snap, "req_total") == 5.0
    assert obs.metric_value(snap, "req_total", kind="a") == 2.0
    assert obs.metric_value(snap, "depth") == 4.0
    hv = snap[("lat", ())]
    assert hv.count == 3 and hv.sum == 55.5
    assert hv.bucket_counts == (1, 2, 3)  # cumulative, last is +inf
    with pytest.raises(TypeError):
        reg.gauge("req_total")  # kind mismatch on an existing name
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registry_diff_and_exports():
    reg = MetricsRegistry()
    reg.counter("c").inc(1, tier="indexed")
    reg.gauge("g").set(7)
    reg.histogram("h").observe(3.0)
    before = reg.snapshot()
    reg.counter("c").inc(4, tier="indexed")
    reg.counter("c").inc(2, tier="dense")  # label set born after the snapshot
    reg.gauge("g").set(9)
    reg.histogram("h").observe(5.0)
    d = reg.diff(before)
    assert obs.metric_value(d, "c", tier="indexed") == 4.0
    assert obs.metric_value(d, "c", tier="dense") == 2.0
    assert obs.metric_value(d, "g") == 9.0  # gauges report current value
    assert obs.metric_value(d, "h") == 1.0  # histogram delta contributes count
    txt = reg.to_prometheus_text()
    assert "# TYPE c counter" in txt
    assert 'c{tier="indexed"} 5' in txt
    assert 'h_bucket{le="+Inf"} 2' in txt
    assert "h_sum 8.0" in txt and "h_count 2" in txt
    doc = json.loads(reg.to_json())
    assert {m["name"] for m in doc} == {"c", "g", "h"}


# -- chrome trace + report ---------------------------------------------------

def test_chrome_trace_roundtrips_through_report(tmp_path):
    with obs.capture() as cap:
        with obs.span("phase.a", "plan", worker=0, round=1):
            obs.event("tick", "retry")
    path = str(tmp_path / "trace.json")
    cap.write_chrome_trace(path)
    events = obs_report.load_trace(path)
    rep = obs_report.build_report(events)
    assert rep["num_spans"] == 1 and rep["num_instants"] == 1
    assert rep["phases"]["plan"]["phase.a"]["count"] == 1
    assert rep["workers"]["0"]["count"] == 1
    assert rep["rounds"]["1"]["count"] == 1
    text = obs_report.format_report(rep)
    assert "phase.a" in text and "worker" in text
    # the CLI entry point agrees, in both output modes
    assert obs_report.main([path]) == 0
    assert obs_report.main([path, "--json"]) == 0


@pytest.mark.parametrize("doc,msg", [
    ([{"name": "x"}], "no phase"),
    ([{"ph": "X", "name": "x", "ts": 0}], "bad dur"),
    ([{"ph": "X", "ts": 0, "dur": 1}], "no name"),
    ([{"ph": "i", "name": "x", "ts": "zero"}], "non-numeric ts"),
    ({"foo": []}, "missing 'traceEvents'"),
    ("nope", "top level"),
])
def test_malformed_trace_fails(tmp_path, doc, msg):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(obs_report.TraceFormatError, match=msg):
        obs_report.load_trace(path)
    assert obs_report.main([path]) == 1


# -- engine parity matrix ----------------------------------------------------

@pytest.mark.parametrize("execution", ["indexed", "dense"])
def test_engine_dispatch_span_parity(dataset_case, execution):
    name, data, eps = dataset_case
    cfg = SelfJoinConfig(eps=eps, k=4, tile_size=16, execution=execution)
    eng = SelfJoinEngine(data, cfg)
    with obs.capture() as cap:
        cres = eng.count()
        pres = eng.pairs()
    expect = (
        cres.stats.num_device_dispatches + pres.stats.num_device_dispatches
    )
    assert cap.span_count(cat="dispatch") == expect, name
    assert cap.metric("selfjoin_device_dispatches_total", path="engine") == expect
    assert cap.metric("selfjoin_joins_total", path="engine") == 2
    assert (
        cap.metric("selfjoin_results_total", path="engine", mode="pairs")
        == pres.stats.num_results
    )
    np.testing.assert_array_equal(cres.counts, brute_counts(data, eps))
    assert pair_set(pres.pairs) == pair_set(brute_pairs(data, eps)), name


def test_engine_overflow_retry_events():
    d = make_dataset("clustered", 301, 8, seed=7)
    eng = SelfJoinEngine(d, SelfJoinConfig(eps=0.25, k=4, tile_size=16))
    truth = pair_set(brute_pairs(d, 0.25))
    with obs.capture() as cap:
        res = eng.pairs(_cap_hint=1)  # undersized buffer: grow-and-retry
    assert res.stats.overflow_retries >= 1
    assert cap.span_count(cat="retry") == res.stats.overflow_retries
    # dispatch spans count launches across ALL attempts, matching the stats
    assert cap.span_count(cat="dispatch") == res.stats.num_device_dispatches
    assert (
        cap.metric("selfjoin_overflow_retries_total", path="engine")
        == res.stats.overflow_retries
    )
    assert pair_set(res.pairs) == truth


# -- distributed ring parity -------------------------------------------------

def test_host_ring_round_spans_and_parity():
    d = make_dataset("exponential", 403, 16, seed=5)
    de = DistributedSelfJoinEngine(
        d, SelfJoinConfig(eps=0.06, k=4, tile_size=16), num_workers=4
    )
    with obs.capture() as cap:
        cres = de.count()
        pres = de.self_join_pairs()
    expect = (
        cres.stats.num_device_dispatches + pres.stats.num_device_dispatches
    )
    assert cap.span_count(cat="dispatch") == expect
    assert cap.metric("selfjoin_device_dispatches_total", path="ring_host") == expect
    # one ring.round span per BSP round, both modes, rounds labelled 0..p-1
    rounds = cap.spans("ring.round", "ring")
    assert len(rounds) == 2 * 4
    assert {e.attrs["round"] for e in rounds} == {0, 1, 2, 3}
    assert {e.attrs["mode"] for e in rounds} == {"count", "pairs"}
    np.testing.assert_array_equal(cres.counts, brute_counts(d, 0.06))


def test_fused_ring_parity_one_device():
    d = make_dataset("clustered", 403, 32, seed=22)
    de = DistributedSelfJoinEngine(
        d, SelfJoinConfig(eps=0.25, k=4, tile_size=16), mesh=_mesh1(), fused=True
    )
    with obs.capture() as cap:
        cres = de.count()
        pres = de.self_join_pairs()
    expect = (
        cres.stats.num_device_dispatches + pres.stats.num_device_dispatches
    )
    assert cap.span_count(cat="dispatch") == expect
    assert cap.metric("selfjoin_device_dispatches_total", path="ring_fused") == expect
    # pack happened inside the capture: per-(worker, round) plan spans exist
    assert cap.span_count("ring.pack", "plan") >= 1
    assert cap.span_count("ring.pack.plan", "ring") >= 1
    # fused programs announce their (re)traces as compile events
    programs = {e.attrs["program"] for e in cap.spans("ring.trace", "compile")}
    assert programs == {"fused_count", "fused_pairs"}
    assert pair_set(pres.pairs) == pair_set(brute_pairs(d, 0.25))


# -- service stream parity ---------------------------------------------------

def test_service_stream_parity_and_churn_spans():
    rng = np.random.default_rng(0)
    pts = make_dataset("uniform", 400, 4, seed=9)
    idx = SimilarityIndex(pts, SelfJoinConfig(eps=0.1, k=3, tile_size=16))
    svc = QueryService(idx)
    q0 = make_dataset("uniform", 16, 4, seed=10)
    svc.range_count(q0, 0.1)  # warm one bucket outside the capture

    tr0 = svc.total.num_traces
    dd0 = svc.total.num_device_dispatches
    rq0 = svc.total.num_requests
    with obs.capture() as cap:
        for i in range(100):
            nq = 8 if i % 3 else 16
            q = make_dataset("uniform", nq, 4, seed=100 + i)
            if i % 4 == 0:
                svc.range_pairs(q, 0.1)
            elif i % 4 == 1:
                svc.knn(q[:4], 3)
            else:
                svc.range_count(q, 0.1)
            if i % 25 == 10:
                idx.insert(rng.random((5, 4), dtype=np.float32))
            if i % 40 == 30:
                idx.delete(idx.insert(rng.random((2, 4), dtype=np.float32)))
    d_tr = svc.total.num_traces - tr0
    d_dd = svc.total.num_device_dispatches - dd0
    d_rq = svc.total.num_requests - rq0
    assert d_rq == 100
    assert cap.span_count(cat="trace") == d_tr
    assert cap.span_count(cat="dispatch") == d_dd
    assert cap.metric("service_traces_total") == d_tr
    assert cap.metric("service_dispatches_total") == d_dd
    assert cap.metric("service_requests_total") == 100
    assert cap.span_count("service.request", "request") == 100
    assert cap.span_count("service.request", "log") == 100
    assert cap.span_count("service.pin", "service") == 100
    assert cap.span_count("service.unpin", "service") == 100
    # churn instrumentation: inserts/deletes landed as index spans + counters
    assert cap.span_count("index.insert", "index") == 6
    assert cap.span_count("index.delete", "index") == 2
    assert cap.metric("index_inserts_total") == 4 * 5 + 2 * 2
    assert cap.metric("index_deletes_total") == 2 * 2
    assert cap.dropped == 0
    # per-request kinds all mirrored under their own label
    for kind in ("range_count", "range_pairs", "knn"):
        assert cap.metric("service_requests_total", kind=kind) > 0


def test_index_auto_compact_span():
    pts = make_dataset("uniform", 64, 3, seed=2)
    idx = SimilarityIndex(
        pts, SelfJoinConfig(eps=0.2, k=2, tile_size=16), auto_compact_fraction=0.25
    )
    with obs.capture() as cap:
        idx.insert(make_dataset("uniform", 40, 3, seed=3))  # trips the spill
    assert idx.auto_compactions >= 1
    assert cap.span_count("index.auto_compact", "index") == idx.auto_compactions
    assert cap.span_count("index.prepare_compact", "index") == idx.auto_compactions
    assert cap.span_count("index.apply_compact", "index") == idx.auto_compactions
    assert cap.metric("index_auto_compactions_total") == idx.auto_compactions
    assert cap.metric("index_compactions_total") == idx.auto_compactions


# -- 8-device acceptance matrix (subprocess; flag must precede jax init) -----

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, sys.argv[1])
    sys.path.insert(0, sys.argv[2])
    import json
    import numpy as np, jax
    from oracles import brute_pairs, make_dataset, pair_set
    from repro import obs
    from repro.core import DistributedSelfJoinEngine, SelfJoinConfig
    from repro.obs import report as obs_report

    mesh = jax.make_mesh((8,), ("data",))
    d = make_dataset("exponential", 501, 16, seed=21)
    de = DistributedSelfJoinEngine(
        d, SelfJoinConfig(eps=0.06, k=4, tile_size=16), mesh=mesh, fused=True
    )
    with obs.capture() as cap:
        res = de.self_join_pairs()
    assert pair_set(res.pairs) == pair_set(brute_pairs(d, 0.06))
    # one fused dispatch span per device launch, mirrored to the registry
    assert cap.span_count(cat="dispatch") == res.stats.num_device_dispatches == 1
    assert cap.metric(
        "selfjoin_device_dispatches_total", path="ring_fused"
    ) == 1
    # per-(worker, round) pack spans cover the full 8-round ring schedule
    packs = cap.spans("ring.pack.plan", "ring")
    rounds = {e.attrs["round"] for e in packs}
    workers = {e.attrs["worker"] for e in packs}
    assert rounds == set(range(8)), rounds
    assert workers == set(range(8)), workers
    # the capture round-trips through the exporter and the report CLI
    path = os.path.join(sys.argv[3], "trace8.json")
    cap.write_chrome_trace(path)
    rep = obs_report.build_report(obs_report.load_trace(path))
    assert rep["num_spans"] >= len(packs)
    assert set(rep["rounds"]) == {str(r) for r in range(8)}
    assert "dispatch" in rep["phases"]
    assert obs_report.main([path]) == 0
    print("OBS_8DEV_OK")
    """
)


def test_obs_fused_pairs_8_devices(tmp_path):
    here = os.path.dirname(__file__)
    src = os.path.join(here, "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, src, here, str(tmp_path)],
        capture_output=True, text=True, timeout=600,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OBS_8DEV_OK" in out.stdout
