"""PartitionSpec rules: DP over ("pod","data"), TP/EP over "model".

Baseline sharding (hillclimbed variants live in launch/dryrun overrides):

  * embeddings/unembed: vocab over "model"
  * attention/MLP in-projections: output features over "model"
  * out-projections: input features over "model"
  * MoE expert stacks: expert axis over "model" (expert parallelism)
  * FSDP (>=236B configs): the remaining large dim over "data"
    (params+optimizer state sharded; gathered per layer by GSPMD)
  * KV caches: head_dim over "model", batch over DP axes
  * recurrent states: feature dim over "model", batch over DP

Every rule is divisibility-guarded: a dim is only sharded if divisible by
the mesh axis size (e.g. qwen2.5's 40 heads shard as the flattened 5120-wide
head*dh dim, not the head count).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _div(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
    else:
        size = mesh.shape[axis]
    return size > 0 and n % size == 0


def _guard(spec_axes, shape, mesh: Mesh) -> P:
    """Drop any axis the dim size doesn't divide."""
    out = []
    for dim, ax in zip(shape, spec_axes):
        out.append(ax if (ax is not None and _div(dim, mesh, ax)) else None)
    return P(*out)


# parameter-name classes
_IN_PROJ = {
    "wq", "wk", "wv", "wg", "wi", "wog", "wuq", "wukv", "wzifo",
    "win1", "win2", "wa", "wx",
}
_OUT_PROJ = {"wo", "wout"}
_REPLICATED = {"router", "wkr", "wdq", "wdkv", "xgate", "b", "lam"}


def _leaf_spec(path: Tuple[str, ...], shape, mesh: Mesh, fsdp: bool,
               stack_depth: int) -> P:
    """path: dict keys from the root to this leaf (group indices removed)."""
    fs = "data" if (fsdp and "data" in mesh.shape) else None
    names = [p for p in path if isinstance(p, str)]
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    lead = (None,) * stack_depth
    nd = len(shape) - stack_depth
    body = shape[stack_depth:]

    def make(*axes):
        return _guard(lead + axes, shape, mesh)

    if leaf in ("scale", "bias", "lam", "xgate") or parent in ("qnorm", "knorm", "norm", "ln1", "ln2", "lnx", "final_norm", "enc_norm", "kvnorm"):
        # norm params: shard 1-D over model only if large (d_rnn/d_inner)
        if nd == 1 and body[0] % max(mesh.shape.get("model", 1), 1) == 0 and body[0] >= 1024:
            return make("model")
        return P(*((None,) * len(shape)))
    if leaf == "table":  # embedding (vocab, d)
        return make("model", fs)
    if parent == "unembed" and leaf == "w":
        return make(fs, "model")
    if parent == "router":
        return P(*((None,) * len(shape)))
    if leaf == "w" and parent in _IN_PROJ:
        return make(fs, "model")
    if leaf == "w" and parent in _OUT_PROJ:
        return make("model", fs)
    if leaf == "w" and parent in _REPLICATED:
        return make(fs, None)
    if leaf == "w" and parent == "conv":
        return make(None, "model")
    if leaf in ("wg", "wi") and nd == 3:   # MoE experts (E, d, f)
        return make("model", fs, None)
    if leaf == "wo" and nd == 3:           # MoE experts (E, f, d)
        return make("model", None, fs)
    if leaf == "r" and nd == 4:            # sLSTM recurrent (4, H, dh, dh)
        return make(None, "model", None, None)
    if leaf == "b":
        return P(*((None,) * len(shape)))
    # fallback: shard the largest dim over model if divisible
    if nd >= 1:
        body_axes: list = [None] * nd
        big = max(range(nd), key=lambda i: body[i])
        body_axes[big] = "model"
        return make(*body_axes)
    return P(*((None,) * len(shape)))


def _stack_depth_of_path(path) -> int:
    """Params under groups/<g>/<pos> are stacked with one leading repeat axis."""
    keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
    return 1 if ("groups" in keys or "enc_groups" in keys) else 0


def param_specs(params_tree, mesh: Mesh, fsdp: bool = False):
    """Pytree of PartitionSpec matching ``params_tree``."""

    def spec(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else p.idx if hasattr(p, "idx") else str(p)
            for p in path
        )
        sd = _stack_depth_of_path(path)
        return _leaf_spec(keys, leaf.shape, mesh, fsdp, sd)

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def cache_specs(cache_tree, mesh: Mesh):
    """KV caches / recurrent states: batch over DP, features over model."""
    dp = dp_axes(mesh)

    def spec(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        name = next((k for k in reversed(keys) if isinstance(k, str)), "")
        shape = leaf.shape
        # all stacked caches have a leading (repeat,) axis then batch
        if name == "pos":
            return P(*((None,) * len(shape)))
        axes = [None] * len(shape)
        if len(shape) >= 2:
            axes[1] = dp if _div(shape[1], mesh, dp) else None
        if len(shape) == 5:
            # (repeat, B, S, KV, dh) attention cache: prefer KV-head sharding
            # when divisible -- dh-sharding makes GSPMD reshard the cache to
            # head layout every layer (EXPERIMENTS.md #Perf, decode addendum)
            if _div(shape[3], mesh, "model"):
                axes[3] = "model"
            elif _div(shape[4], mesh, "model"):
                axes[4] = "model"
        elif len(shape) >= 3:
            last = len(shape) - 1
            axes[last] = "model" if _div(shape[last], mesh, "model") else None
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def batch_spec(batch_tree, mesh: Mesh):
    """Input batches: leading batch dim over DP axes."""
    dp = dp_axes(mesh)

    def spec(leaf):
        axes = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and _div(leaf.shape[0], mesh, dp):
            axes[0] = dp
        return P(*axes)

    return jax.tree_util.tree_map(spec, batch_tree)
