from repro.sharding.rules import (  # noqa: F401
    param_specs,
    cache_specs,
    batch_spec,
    dp_axes,
)
