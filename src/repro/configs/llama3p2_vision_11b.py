"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.  Gated cross-attention image layers every 5th layer; the vision
tower is a STUB per the assignment: ``input_specs()`` provides precomputed
patch embeddings (B, vision_tokens, vision_dim).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
import dataclasses

from repro.models.config import BlockCfg, ModelConfig

_SELF = BlockCfg(kind="attn", rope_theta=500_000.0)
_XCROSS = BlockCfg(kind="attn", cross_attn=True, rope_theta=500_000.0)


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        vocab=128_256,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14_336,
        groups=(((_SELF,) * 4 + (_XCROSS,), 8),),  # 40 layers, cross every 5th
        vision_tokens=1601,       # 1 tile x (40x40 patches + cls)
        vision_dim=1280,
        max_seq=131_072,
        family="vlm",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        groups=(((_SELF, _XCROSS), 2),),
        vision_tokens=16, vision_dim=32,
        max_seq=128, q_chunk=16, k_chunk=16, remat=False,
    )
