"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks; we use the xLSTM[3:1] layout (3 mLSTM : 1 sLSTM,
pattern of 4 repeated 3x).  d_ff=0: blocks carry their own up-projection
(mLSTM inner dim 2*d_model), no separate FFN.  [arXiv:2405.04517; unverified]
"""
import dataclasses

from repro.models.config import BlockCfg, ModelConfig

_M = BlockCfg(kind="mlstm", mlp=False)
_S = BlockCfg(kind="slstm", mlp=False)


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        vocab=50_304,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        groups=(((_M, _M, _M, _S), 3),),  # 12 layers
        tie_embeddings=True,
        max_seq=1_048_576,                # recurrent state: long-context capable
        family="ssm",
        sub_quadratic=True,               # runs long_500k
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=2, num_kv_heads=2,
        groups=(((_M, _S), 2),),
        max_seq=128, q_chunk=16, k_chunk=16, remat=False,
    )
