"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA (kv_lora=512)
per-expert d_ff=1536 vocab=102400, MoE 160 routed top-6 + 2 shared experts;
first layer dense (d_ff 12288).  [arXiv:2405.04434; hf]
"""
import dataclasses

from repro.models.config import BlockCfg, MLACfg, MoECfg, ModelConfig

_DENSE = BlockCfg(kind="attn", moe=False)
_MOE = BlockCfg(kind="attn", moe=True)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        vocab=102_400,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=12_288,             # the single dense layer's FFN
        groups=(
            ((_DENSE,), 1),
            ((_MOE,), 59),
        ),
        mla=MLACfg(
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoECfg(num_experts=160, top_k=6, expert_ff=1536, num_shared=2),
        max_seq=131_072,
        param_dtype="bfloat16",
        opt_state_dtype="bfloat16",
        family="moe",
        sub_quadratic=False,
        # EXPERIMENTS.md #Perf cell C: larger flash chunks cut the 32k-prefill
        # memory term ~1.8x (fewer chunk-pair relayouts) and still fit HBM
        q_chunk=1024,
        k_chunk=4096,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        groups=(((_DENSE,), 1), ((_MOE,), 2)),
        mla=MLACfg(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
                   qk_rope_head_dim=8, v_head_dim=16),
        moe=MoECfg(num_experts=8, top_k=2, expert_ff=64, num_shared=1),
        max_seq=128, q_chunk=16, k_chunk=16, remat=False,
        param_dtype="float32", opt_state_dtype="float32",
    )
