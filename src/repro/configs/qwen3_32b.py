"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936.  qk_norm.  [hf:Qwen/Qwen3-8B family; hf]
"""
import dataclasses

from repro.models.config import BlockCfg, ModelConfig

_BLK = BlockCfg(kind="attn", rope_theta=1_000_000.0)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        vocab=151_936,
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=25_600,
        groups=(((_BLK,), 64),),
        qk_norm=True,
        max_seq=131_072,
        family="dense",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, groups=(((_BLK,), 3),), max_seq=128, q_chunk=16, k_chunk=16,
        remat=False,
    )
