"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

5:1 local:global attention (window 1024), RoPE theta 10k local / 1M global,
qk-norm, tied embeddings.  [hf:google/gemma-3-1b-pt family; unverified]
"""
import dataclasses

from repro.models.config import BlockCfg, ModelConfig

_LOCAL = BlockCfg(kind="attn", window=1024, rope_theta=10_000.0)
_GLOBAL = BlockCfg(kind="attn", rope_theta=1_000_000.0)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        vocab=262_144,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=15_360,
        groups=(((_LOCAL,) * 5 + (_GLOBAL,), 8),),  # 48 layers = 8 x (5L+1G)
        qk_norm=True,
        tie_embeddings=True,
        logit_softcap=30.0,
        max_seq=131_072,
        family="dense",
        sub_quadratic=False,   # global layers are full attention -> skip long_500k
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        groups=(((dataclasses.replace(_LOCAL, window=8),) * 2
                 + (dataclasses.replace(_GLOBAL),), 2),),
        max_seq=128,
        q_chunk=16,
        k_chunk=16,
        remat=False,
    )
