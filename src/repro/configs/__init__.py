"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

One module per assigned architecture with the exact published configuration,
plus ``reduced()`` variants for CPU smoke tests and the paper's own self-join
configuration (``selfjoin.py``).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCH_IDS: List[str] = [
    "gemma3_12b",
    "phi3_mini_3p8b",
    "qwen3_32b",
    "qwen2p5_32b",
    "recurrentgemma_2b",
    "arctic_480b",
    "deepseek_v2_236b",
    "seamless_m4t_medium",
    "llama3p2_vision_11b",
    "xlstm_125m",
]

_ALIASES: Dict[str, str] = {
    "gemma3-12b": "gemma3_12b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "qwen3-32b": "qwen3_32b",
    "qwen2.5-32b": "qwen2p5_32b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "arctic-480b": "arctic_480b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-11b": "llama3p2_vision_11b",
    "xlstm-125m": "xlstm_125m",
}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.config()


def get_reduced_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.reduced()
