"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000.  RG-LRU + local attention, pattern 2 recurrent : 1 attention
(window 2048).  [arXiv:2402.19427; hf]

26 layers = 8 x (rec, rec, attn) + (rec, rec).
"""
import dataclasses

from repro.models.config import BlockCfg, ModelConfig

_REC = BlockCfg(kind="recurrent")
_ATT = BlockCfg(kind="attn", window=2048)


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        vocab=256_000,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        d_rnn=2560,
        conv_width=4,
        groups=(
            ((_REC, _REC, _ATT), 8),
            ((_REC, _REC), 1),
        ),
        tie_embeddings=True,
        max_seq=1_048_576,       # state is O(window): long-context capable
        family="hybrid",
        sub_quadratic=True,      # runs long_500k
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, d_rnn=64,
        groups=(((_REC, dataclasses.replace(_ATT, window=8)), 2),),
        max_seq=128, q_chunk=16, k_chunk=16, remat=False,
    )
