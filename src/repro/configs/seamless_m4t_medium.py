"""seamless-m4t-medium [audio]: enc-dec, 12L each, d_model=1024 16H (MHA)
d_ff=4096 vocab=256206.  The speech frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, S_frames, 1024).
[arXiv:2308.11596; hf]
"""
import dataclasses

from repro.models.config import BlockCfg, ModelConfig

_ENC = BlockCfg(kind="attn", bidirectional=True)
_DEC = BlockCfg(kind="attn", cross_attn=True)


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        vocab=256_206,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        groups=(((_DEC,), 12),),
        encoder_groups=(((_ENC,), 12),),
        enc_input_dim=1024,
        max_seq=8192,
        family="audio",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        groups=(((_DEC,), 2),), encoder_groups=(((_ENC,), 2),),
        enc_input_dim=64, max_seq=128, q_chunk=16, k_chunk=16, remat=False,
    )
