"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32, MHA) d_ff=8192
vocab=32064.  RoPE SwiGLU.  [arXiv:2404.14219; unverified]
"""
import dataclasses

from repro.models.config import BlockCfg, ModelConfig

_BLK = BlockCfg(kind="attn")


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        vocab=32_064,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        groups=(((_BLK,), 32),),
        max_seq=131_072,
        family="dense",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        groups=(((_BLK,), 3),), max_seq=128, q_chunk=16, k_chunk=16,
        remat=False,
    )
