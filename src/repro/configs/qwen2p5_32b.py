"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064.  GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B family; hf]
"""
import dataclasses

from repro.models.config import BlockCfg, ModelConfig

_BLK = BlockCfg(kind="attn", rope_theta=1_000_000.0)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        vocab=152_064,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=27_648,
        groups=(((_BLK,), 64),),
        qkv_bias=True,
        max_seq=131_072,
        family="dense",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        groups=(((_BLK,), 3),), max_seq=128, q_chunk=16, k_chunk=16,
        remat=False,
    )
