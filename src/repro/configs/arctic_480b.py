"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual MLP per layer (dense-MoE hybrid).
[hf:Snowflake/snowflake-arctic-base; hf]

bf16 optimizer state: 480B params x 14B/param of fp32 AdamW would exceed a
256-chip v5e pod's 4 TB HBM; bf16 m/v + bf16 params (6 B/param) fits
(DESIGN.md #4).
"""
import dataclasses

from repro.models.config import BlockCfg, MoECfg, ModelConfig

_BLK = BlockCfg(kind="attn", moe=True)


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        vocab=32_000,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,               # dense residual path
        groups=(((_BLK,), 35),),
        moe=MoECfg(
            num_experts=128,
            top_k=2,
            expert_ff=4864,
            dense_residual_ff=4864,
        ),
        max_seq=131_072,
        param_dtype="bfloat16",
        opt_state_dtype="bfloat16",
        family="moe",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        vocab=512, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, groups=(((_BLK,), 2),),
        moe=MoECfg(num_experts=8, top_k=2, expert_ff=96, dense_residual_ff=96),
        max_seq=128, q_chunk=16, k_chunk=16, remat=False,
        param_dtype="float32", opt_state_dtype="float32",
    )
