"""Result-set sizing and batching (paper Section 3.2.2).

The paper sizes batches by first running an *estimate kernel* over a fraction
of the points (returning only a count), then splits the join into
``n_b = max(3, ceil(|R_est| / b_s))`` batches so the result set never
overflows device memory and transfers overlap compute.  Here the estimate
evaluates a random sample of candidate tile pairs (counts only -- the cheap
kernel); ``estimate_result_size`` accepts host or device tile arrays, so the
engine can estimate without leaving the accelerator.

Two consumers:

  * the device-resident ``SelfJoinEngine`` uses the estimate to preallocate
    its pairs buffer (``suggest_pairs_capacity``); its chunking itself is
    fixed-size (one compiled program per chunk shape, see
    ``repro.core.engine``), so no batch-count decision is needed there;
  * the legacy host-loop path (``selfjoin.self_join_hostloop``) still uses
    ``compute_num_batches`` / ``batch_ranges`` exactly as the paper does --
    on real hardware consecutive batches are dispatched asynchronously so
    D2H copies of batch i overlap the kernel of batch i+1 (paper Fig. 4).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.kernels import ops


def estimate_result_size(
    tiles_pts: np.ndarray,
    tile_len: np.ndarray,
    plan,
    *,
    eps: float,
    dim_block: int,
    backend: str,
    sample_frac: float = 0.01,
    seed: int = 0,
    interpret: bool = True,
) -> int:
    """Estimated |R| from a sample of candidate tile pairs (counts only)."""
    p = plan.num_pairs
    if p == 0:
        return 0
    n_sample = max(1, min(p, int(round(p * max(sample_frac, 1e-6)))))
    rng = np.random.default_rng(seed)
    sel = rng.choice(p, size=n_sample, replace=False)
    counts, _ = ops.tile_counts(
        tiles_pts, tile_len, plan.pair_a[sel], plan.pair_b[sel],
        eps=eps, dim_block=dim_block, shortc=True, backend=backend,
        interpret=interpret,
    )
    return int(round(float(counts.sum()) * (p / n_sample)))


def suggest_pairs_capacity(
    estimated_results: int, headroom: float = 2.0, floor: int = 4096
) -> int:
    """Pairs-buffer rows to preallocate for an estimated |R|.

    Headroom absorbs sampling error; the result is rounded up to a multiple
    of ``floor`` so repeated auto-sizing lands on few distinct buffer shapes
    (each distinct capacity is one more compiled pairs program).
    """
    want = int(max(estimated_results, 1) * max(headroom, 1.0))
    return max(floor, -(-want // floor) * floor)


def compute_num_batches(
    estimated_results: int, batch_size: int, min_batches: int = 3
) -> int:
    """n_b >= 3 always (the paper pipelines with >= 3 CUDA streams)."""
    by_size = -(-max(estimated_results, 1) // max(batch_size, 1))
    return max(min_batches, by_size)


def batch_ranges(num_pairs: int, num_batches: int) -> Iterator[Tuple[int, int]]:
    """Split [0, num_pairs) into num_batches near-equal contiguous ranges."""
    num_batches = max(1, min(num_batches, max(num_pairs, 1)))
    step = -(-num_pairs // num_batches)
    for lo in range(0, num_pairs, step):
        yield lo, min(lo + step, num_pairs)
    if num_pairs == 0:
        yield 0, 0
