"""Grid index over the first k dimensions (paper Sections 3.2.1 and 4.1).

Construction happens on the host, exactly as in the paper ("On the host, the
data points D are sorted into unit-length bins in each dimension").  Only
non-empty cells are stored; points are kept in a lookup array sorted by
(linearized cell id, u-coordinate), so cell-mates are contiguous in memory --
the property the paper uses for coalescing and we use for sequential VMEM DMA.

TPU adaptation (DESIGN.md #1.1): the per-thread 3^k adjacent-cell walk of the
CUDA kernel becomes *candidate tile-pair generation*: every non-empty cell is
split into fixed-size tiles and each (cell, adjacent cell) pair contributes
its tile cross-product to a flat work list that the distance kernel consumes
as dense, regular MXU work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

_MAX_LINEAR = np.int64(2) ** 62


@dataclasses.dataclass
class GridIndex:
    """Non-empty-cell grid over the first ``k`` dims of the (reordered) data."""

    eps: float
    k: int
    n: int
    u_dim: int                     # SORTIDU dimension (first un-indexed, or last indexed if k == n)
    origin: np.ndarray             # (k,) int64 cell-coordinate offset (per-dim min)
    cells_per_dim: np.ndarray      # (k,) int64
    strides: np.ndarray            # (k,) int64
    point_order: np.ndarray        # (N,) int64; pts_sorted[i] == D[point_order[i]]
    pts_sorted: np.ndarray         # (N, n) float32
    cell_coords: np.ndarray        # (C, k) int64 coords of non-empty cells, id-sorted
    cell_ids: np.ndarray           # (C,) int64 sorted linearized ids
    cell_start: np.ndarray         # (C,) int64 into pts_sorted
    cell_count: np.ndarray         # (C,) int64

    @property
    def num_cells(self) -> int:
        return int(self.cell_ids.shape[0])

    @property
    def bin_width(self) -> float:
        """Cell edge length (eps, or 1.0 for the degenerate eps == 0 grid)."""
        return self.eps if self.eps > 0 else 1.0

    @property
    def data_bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-dimension (min, max) of the indexed points, reordered frame.

        The serving tier's kNN search (``repro.join``) uses this to cap its
        eps expansion: the diagonal of the joint query/data bounding box is
        an upper bound on any pairwise distance, so one pass at that radius
        is guaranteed to see every point.
        """
        got = getattr(self, "_bounds_cache", None)
        if got is None:
            if self.pts_sorted.shape[0] == 0:
                z = np.zeros(self.n, np.float64)
                got = (z, z)
            else:
                pts = self.pts_sorted.astype(np.float64)
                got = (pts.min(axis=0), pts.max(axis=0))
            self._bounds_cache = got  # static per grid; rebuilds make a new one
        return got


@dataclasses.dataclass
class QueryTilePlan:
    """Bipartite work list: evaluate q_sorted[Q tile] x pts_sorted[D tile].

    The distributed tier's per-round local join (DESIGN.md #7): external
    query points Q are binned into an existing ``GridIndex`` over D, and the
    candidate set is the 3^k adjacent-cell cross product at tile granularity
    -- the same index filtering as the self-join, for an arbitrary query set.
    ``pair_q`` indexes the query tiling here; ``pair_d`` indexes the data
    grid's own ``TilePlan`` tiles.
    """

    tile_size: int
    q_order: np.ndarray            # (Nq,) int64; q_sorted[i] == Q[q_order[i]]
    q_sorted: np.ndarray           # (Nq, n) float32, cell- then u-sorted
    q_tile_start: np.ndarray       # (num_q_tiles,) int32 into q_sorted
    q_tile_len: np.ndarray         # (num_q_tiles,) int32, 1..tile_size
    pair_q: np.ndarray             # (P,) int32 query-tile index
    pair_d: np.ndarray             # (P,) int32 data-tile index (into TilePlan)
    num_tile_pairs_total: int      # before SORTIDU window pruning
    num_candidates: int            # sum(q_len * d_len) over evaluated pairs

    @property
    def num_q_tiles(self) -> int:
        return int(self.q_tile_start.shape[0])

    @property
    def num_pairs(self) -> int:
        return int(self.pair_q.shape[0])


@dataclasses.dataclass
class TilePlan:
    """Flat candidate work list: evaluate pts[A tile] x pts[B tile] pairs."""

    tile_size: int
    tile_start: np.ndarray         # (num_tiles,) int32 into pts_sorted
    tile_len: np.ndarray           # (num_tiles,) int32, 1..tile_size
    tile_cell: np.ndarray          # (num_tiles,) int32 owning cell index
    pair_a: np.ndarray             # (P,) int32 tile index
    pair_b: np.ndarray             # (P,) int32 tile index
    num_tile_pairs_total: int      # before SORTIDU window pruning
    num_candidates: int            # sum(len_a * len_b) over evaluated pairs

    @property
    def num_tiles(self) -> int:
        return int(self.tile_start.shape[0])

    @property
    def num_pairs(self) -> int:
        return int(self.pair_a.shape[0])


def build_grid(d: np.ndarray, eps: float, k: int) -> GridIndex:
    """Assign points to eps-length cells in the first k dims and sort them.

    Cell coordinates are ``floor(x_j / eps)`` (paper Sec. 3.2.1).  Points
    within a cell are secondarily sorted by the u-coordinate (SORTIDU,
    Sec. 4.3); u is the first un-indexed dimension (highest-variance one
    after REORDER) or the last indexed dimension when k == n.
    """
    pts = np.ascontiguousarray(np.asarray(d, dtype=np.float32))
    n_pts, n = pts.shape
    k = int(min(k, n))
    u_dim = k if k < n else n - 1

    # eps == 0 (duplicate join): bin at unit width -- any positive cell
    # width is correct for a radius not exceeding it.
    bin_width = eps if eps > 0 else 1.0
    coords = np.floor(pts[:, :k].astype(np.float64) / bin_width).astype(np.int64)
    if n_pts:
        cmin = coords.min(axis=0)
        coords -= cmin  # origin at 0 per dim
        cells_per_dim = coords.max(axis=0).astype(np.int64) + 1
    else:
        cmin = np.zeros(k, dtype=np.int64)
        cells_per_dim = np.ones(k, dtype=np.int64)

    # linearization strides; fall back to row-rank ids on (theoretical) overflow
    total = np.prod(cells_per_dim.astype(object))
    if total < int(_MAX_LINEAR):
        strides = np.ones(k, dtype=np.int64)
        for j in range(k - 2, -1, -1):
            strides[j] = strides[j + 1] * cells_per_dim[j + 1]
        ids = coords @ strides
    else:  # pragma: no cover - only hit for k*log2(cells) > 62
        strides = np.zeros(k, dtype=np.int64)
        _, ids = np.unique(coords, axis=0, return_inverse=True)
        ids = ids.astype(np.int64)

    order = np.lexsort((pts[:, u_dim], ids))
    ids_sorted = ids[order]
    pts_sorted = np.ascontiguousarray(pts[order])

    uniq_ids, first, counts = np.unique(
        ids_sorted, return_index=True, return_counts=True
    )
    cell_coords = coords[order][first] if n_pts else np.zeros((0, k), np.int64)

    return GridIndex(
        eps=float(eps),
        k=k,
        n=n,
        u_dim=u_dim,
        origin=cmin,
        cells_per_dim=cells_per_dim,
        strides=strides,
        point_order=order.astype(np.int64),
        pts_sorted=pts_sorted,
        cell_coords=cell_coords,
        cell_ids=uniq_ids,
        cell_start=first.astype(np.int64),
        cell_count=counts.astype(np.int64),
    )


def bucket_rows(n: int, floor: int = 1) -> int:
    """Power-of-two row bucket: smallest pow2 >= max(n, floor, 1).

    The shape-bucket contract of the snapshot/engine split (DESIGN.md #10):
    device tables whose row count depends on the DATA (tile tables, the
    combined-order data segment, dense tiles) are padded to pow2 buckets,
    and a rebuilt snapshot carries the old snapshot's buckets forward as
    floors -- so replacing the data behind a warm engine presents identical
    array shapes to every compiled program as long as the new index still
    fits the bucket.
    """
    return 1 << (max(int(n), int(floor), 1) - 1).bit_length()


def pad_axis0(a: np.ndarray, target: int, fill=0) -> np.ndarray:
    """Pad ``a`` along axis 0 to ``target`` rows with the sentinel ``fill``.

    The uniform-shape contract of the fused distributed ring (DESIGN.md #7):
    every per-(worker, round) tile table and pair list is padded to the
    fleet-wide maximum so a single trace fits all ring positions.  ``fill``
    is 0 for tile lengths (the chunk program's validity mask drops empty
    tiles) and an out-of-range index for scatter maps (``mode="drop"``).
    """
    if a.shape[0] >= target:
        return a
    pad = np.full((target - a.shape[0],) + a.shape[1:], fill, dtype=a.dtype)
    return np.concatenate([a, pad], axis=0)


def _neighbor_offsets(k: int) -> np.ndarray:
    """The (3^k, k) array of {-1, 0, 1} cell-coordinate offsets (Fig. 1)."""
    return np.stack(
        np.meshgrid(*([np.array([-1, 0, 1], dtype=np.int64)] * k), indexing="ij"),
        axis=-1,
    ).reshape(-1, k)


def adjacent_cell_pairs(grid: GridIndex) -> Tuple[np.ndarray, np.ndarray]:
    """All ordered (cell, non-empty adjacent cell) index pairs.

    For every non-empty cell the 3^k neighbourhood (paper Fig. 1) is probed
    with a vectorized binary search into the sorted non-empty ids -- the same
    ``|D| * 3^k * log2(|G|)`` search structure the paper models in Sec. 5.6,
    but amortized per *cell* instead of per point.  The self-join case is
    the bipartite probe applied to the grid's own cells.
    """
    return _probe_query_cells(grid, grid.cell_coords)


def split_cells_into_tiles(
    cell_start: np.ndarray, cell_count: np.ndarray, tile_size: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Split each cell's contiguous point run into fixed-size tiles.

    Returns ``(tile_start, tile_len, tile_cell, cell_tile_first)`` -- the
    shared tiling step of the self-join plan (cells of D vs. themselves) and
    the bipartite query plan (cells of Q vs. cells of D).
    """
    t = int(tile_size)
    counts = cell_count
    n_tiles_per_cell = (counts + t - 1) // t if counts.size else counts
    tile_cell = np.repeat(
        np.arange(cell_start.shape[0], dtype=np.int64), n_tiles_per_cell
    )
    if tile_cell.size:
        cell_tile_first = np.concatenate([[0], np.cumsum(n_tiles_per_cell)[:-1]])
        within = np.arange(tile_cell.size, dtype=np.int64) - cell_tile_first[tile_cell]
        tile_start = cell_start[tile_cell] + within * t
        tile_end = np.minimum(tile_start + t, cell_start[tile_cell] + counts[tile_cell])
        tile_len = tile_end - tile_start
    else:
        cell_tile_first = np.zeros(0, np.int64)
        tile_start = np.zeros(0, np.int64)
        tile_len = np.zeros(0, np.int64)
    return tile_start, tile_len, tile_cell, cell_tile_first


def _expand_cell_pairs_to_tile_pairs(
    ca: np.ndarray,
    cb: np.ndarray,
    n_tiles_per_cell_a: np.ndarray,
    n_tiles_per_cell_b: np.ndarray,
    cell_tile_first_a: np.ndarray,
    cell_tile_first_b: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Expand each (cell a, cell b) pair into its tiles(a) x tiles(b) grid."""
    na, nb = n_tiles_per_cell_a[ca], n_tiles_per_cell_b[cb]
    reps = na * nb
    pair_cell_a = np.repeat(ca, reps)
    pair_cell_b = np.repeat(cb, reps)
    if reps.size:
        offs = np.concatenate([[0], np.cumsum(reps)[:-1]])
        local = np.arange(int(reps.sum()), dtype=np.int64) - np.repeat(offs, reps)
        la = local // np.repeat(nb, reps)
        lb = local % np.repeat(nb, reps)
        pair_a = cell_tile_first_a[pair_cell_a] + la
        pair_b = cell_tile_first_b[pair_cell_b] + lb
    else:
        pair_a = np.zeros(0, np.int64)
        pair_b = np.zeros(0, np.int64)
    return pair_a, pair_b


def build_tile_plan(
    grid: GridIndex,
    tile_size: int,
    sortidu: bool,
    cell_pairs: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> TilePlan:
    """Split cells into tiles and expand cell pairs into tile pairs.

    SORTIDU (Sec. 4.3) is applied at tile granularity: each tile's [min,max]
    u-coordinate window is precomputed (points are u-sorted within cells) and
    a tile pair is pruned when the windows are more than eps apart -- the
    paper's Fig. 3 r..s window, vectorized.
    """
    t = int(tile_size)
    counts = grid.cell_count
    n_tiles_per_cell = (counts + t - 1) // t if counts.size else counts
    tile_start, tile_len, tile_cell, cell_tile_first = split_cells_into_tiles(
        grid.cell_start, counts, t
    )

    if cell_pairs is None:
        cell_pairs = adjacent_cell_pairs(grid)
    ca, cb = cell_pairs

    pair_a, pair_b = _expand_cell_pairs_to_tile_pairs(
        ca, cb, n_tiles_per_cell, n_tiles_per_cell,
        cell_tile_first, cell_tile_first,
    )

    total_pairs = int(pair_a.size)

    if sortidu and pair_a.size:
        u = grid.pts_sorted[:, grid.u_dim]
        # per-tile u window; points are u-sorted within each cell, so the
        # window is [first point, last point] of the tile
        u_lo = u[tile_start]
        u_hi = u[tile_start + tile_len - 1]
        gap_lo = u_lo[pair_b] - u_hi[pair_a]   # b entirely above a
        gap_hi = u_lo[pair_a] - u_hi[pair_b]   # a entirely above b
        keep = np.maximum(gap_lo, gap_hi) <= np.float32(grid.eps)
        pair_a, pair_b = pair_a[keep], pair_b[keep]

    if pair_a.size:
        # group the work list by A tile: consecutive kernel grid steps revisit
        # the same A block, so it stays VMEM-resident and per-pair HBM traffic
        # drops to the B tile alone (EXPERIMENTS.md #Perf, kernel iteration 2)
        order = np.lexsort((pair_b, pair_a))
        pair_a, pair_b = pair_a[order], pair_b[order]

    num_candidates = int((tile_len[pair_a] * tile_len[pair_b]).sum()) if pair_a.size else 0

    return TilePlan(
        tile_size=t,
        tile_start=tile_start.astype(np.int32),
        tile_len=tile_len.astype(np.int32),
        tile_cell=tile_cell.astype(np.int32),
        pair_a=pair_a.astype(np.int32),
        pair_b=pair_b.astype(np.int32),
        num_tile_pairs_total=total_pairs,
        num_candidates=num_candidates,
    )


def _probe_query_cells(
    grid: GridIndex, qcell_coords: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """All (probe cell, adjacent non-empty data cell) index pairs.

    ``qcell_coords`` are in the data grid's coordinate frame (origin
    subtracted) but may lie outside its bounding box -- such probe cells
    still find whichever of their 3^k neighbours fall inside.  Probing the
    grid's own ``cell_coords`` yields the self-join adjacency.
    """
    cq = qcell_coords.shape[0]
    c = grid.num_cells
    if cq == 0 or c == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    k = grid.k
    offsets = _neighbor_offsets(k)
    if not grid.strides.any() and k > 1:  # pragma: no cover - rank-id fallback
        lookup = {tuple(cc): i for i, cc in enumerate(grid.cell_coords)}
        out_q, out_d = [], []
        for i, qc in enumerate(qcell_coords):
            for off in offsets:
                j = lookup.get(tuple(qc + off))
                if j is not None:
                    out_q.append(i)
                    out_d.append(j)
        return np.asarray(out_q, np.int64), np.asarray(out_d, np.int64)

    out_q, out_d = [], []
    for off in offsets:
        ncoords = qcell_coords + off[None, :]
        in_bounds = np.all(
            (ncoords >= 0) & (ncoords < grid.cells_per_dim[None, :]), axis=1
        )
        nids = np.where(in_bounds[:, None], ncoords, 0) @ grid.strides
        pos = np.searchsorted(grid.cell_ids, nids)
        pos_c = np.minimum(pos, c - 1)
        found = in_bounds & (grid.cell_ids[pos_c] == nids)
        src = np.nonzero(found)[0]
        out_q.append(src)
        out_d.append(pos_c[src])
    return np.concatenate(out_q), np.concatenate(out_d)


def build_query_tile_plan(
    grid: GridIndex,
    plan: TilePlan,
    q: np.ndarray,
    sortidu: bool,
) -> QueryTilePlan:
    """Bin query points into ``grid`` and emit the Q-tile x D-tile work list.

    ``q`` must be in the same (reordered) coordinate frame as the points the
    grid was built over.  Queries are grouped by data-grid cell, u-sorted
    within each group (so SORTIDU windows apply on both sides), tiled at
    ``plan.tile_size``, and each (query cell, adjacent non-empty data cell)
    pair contributes its tile cross product.  Correct for any query radius
    not exceeding ``grid.eps`` (the candidate set is a superset; the
    distance filter runs at the queried radius).
    """
    q_pts = np.ascontiguousarray(np.asarray(q, dtype=np.float32))
    nq = q_pts.shape[0]
    t = int(plan.tile_size)
    k = grid.k
    if nq == 0:
        return QueryTilePlan(
            tile_size=t,
            q_order=np.zeros(0, np.int64),
            q_sorted=np.zeros((0, grid.n), np.float32),
            q_tile_start=np.zeros(0, np.int32),
            q_tile_len=np.zeros(0, np.int32),
            pair_q=np.zeros(0, np.int32),
            pair_d=np.zeros(0, np.int32),
            num_tile_pairs_total=0,
            num_candidates=0,
        )

    coords = (
        np.floor(q_pts[:, :k].astype(np.float64) / grid.bin_width).astype(np.int64)
        - grid.origin[None, :]
    )
    # group queries by cell; unique rows handle out-of-box coords robustly
    qcell_coords, inv = np.unique(coords, axis=0, return_inverse=True)
    order = np.lexsort((q_pts[:, grid.u_dim], inv))
    q_sorted = np.ascontiguousarray(q_pts[order])
    qcell_count = np.bincount(inv, minlength=qcell_coords.shape[0]).astype(np.int64)
    qcell_start = np.concatenate([[0], np.cumsum(qcell_count)[:-1]])

    q_tile_start, q_tile_len, _, q_cell_tile_first = split_cells_into_tiles(
        qcell_start, qcell_count, t
    )
    n_q_tiles_per_cell = (qcell_count + t - 1) // t

    # data-side tiling parameters, reconstructed to match ``plan``'s layout
    # (same splitting routine build_tile_plan used, so indices line up)
    d_counts = grid.cell_count
    n_d_tiles_per_cell = (d_counts + t - 1) // t if d_counts.size else d_counts
    _, _, _, d_cell_tile_first = split_cells_into_tiles(
        grid.cell_start, d_counts, t
    )

    cq, cd = _probe_query_cells(grid, qcell_coords)
    pair_q, pair_d = _expand_cell_pairs_to_tile_pairs(
        cq, cd, n_q_tiles_per_cell, n_d_tiles_per_cell,
        q_cell_tile_first, d_cell_tile_first,
    )
    total_pairs = int(pair_q.size)

    if sortidu and pair_q.size:
        uq = q_sorted[:, grid.u_dim]
        uq_lo = uq[q_tile_start]
        uq_hi = uq[q_tile_start + q_tile_len - 1]
        ud = grid.pts_sorted[:, grid.u_dim]
        ud_lo = ud[plan.tile_start[pair_d]]
        ud_hi = ud[plan.tile_start[pair_d] + plan.tile_len[pair_d] - 1]
        gap_lo = ud_lo - uq_hi[pair_q]         # d entirely above q
        gap_hi = uq_lo[pair_q] - ud_hi         # q entirely above d
        keep = np.maximum(gap_lo, gap_hi) <= np.float32(grid.eps)
        pair_q, pair_d = pair_q[keep], pair_d[keep]

    if pair_q.size:
        # group by Q tile (A-side VMEM residency, as in build_tile_plan)
        srt = np.lexsort((pair_d, pair_q))
        pair_q, pair_d = pair_q[srt], pair_d[srt]

    num_candidates = (
        int((q_tile_len[pair_q] * plan.tile_len[pair_d].astype(np.int64)).sum())
        if pair_q.size
        else 0
    )

    return QueryTilePlan(
        tile_size=t,
        q_order=order.astype(np.int64),
        q_sorted=q_sorted,
        q_tile_start=q_tile_start.astype(np.int32),
        q_tile_len=q_tile_len.astype(np.int32),
        pair_q=pair_q.astype(np.int32),
        pair_d=pair_d.astype(np.int32),
        num_tile_pairs_total=total_pairs,
        num_candidates=num_candidates,
    )
