"""Distributed self-join: entity partitioning + ring pass (paper Sec. 6.2/6.3).

The paper's strategy for |D| exceeding one device:

  * every node starts with an entity-partitioned query shard Q_k of |D|/|p|
    points and a copy E_k of the same shard;
  * |p| rounds of BSP supersteps: join Q_k against the entry set currently
    held, then send it to node (k+1) mod |p| and receive from (k-1) mod |p|.

This maps 1:1 onto ``shard_map`` + ``jax.lax.ppermute`` on a ring -- the
collective-permute uses ICI neighbour links only (no all-gather), so peak
per-device memory stays at 2 shards and the per-round communication is
exactly |D|/|p| points, totalling (|p|-1)|D| elements as derived in the paper.
Compute of round i overlaps the permute of round i+1 on real hardware (XLA
schedules the independent ops concurrently).

This module owns the **ring transport**: ``ring_scan`` runs the |p| BSP
supersteps as a ``fori_loop`` whose body consumes an arbitrary *pytree*
payload and whose epilogue ``ppermute``-rotates that payload to the next
ring position.  Three payload flavours ride on it:

  * the dense reference below (``make_ring_counts_fn``): the payload is the
    raw point block and the local join is a blocked brute-force count --
    it evaluates every (Q_k, E_j) point pair, discarding the grid index's
    candidate filtering, and is kept for transport measurement
    (`benchmarks/bench_comm.py`) and as the end-to-end ``shard_map``
    correctness oracle;
  * the production count path (``core/dist_engine.py`` with ``fused=True``,
    DESIGN.md #7a): the payload is the shard's padded *tile table*
    (tiles, tile lengths) and the body is the chunked indexed count
    program -- the whole join is one compiled device program;
  * the pairs path (``self_join_pairs(fused=True)``, DESIGN.md #7b): the
    payload additionally rotates the shard's decode tables (tile starts
    and the global-id grid-sort permutation) and the carry is each
    worker's (pairs buffer, cursor, max-chunk-hits) compaction state, so
    matched (query id, data id) rows accumulate across rounds inside the
    same one program.

Works unchanged on a 1-axis mesh ("data") or the joint ("pod","data") axes of
the production mesh -- the ring simply spans both (inter-pod DCI hops occur
once per pod boundary per round).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat

AxisNames = Union[str, Tuple[str, ...]]


def _local_counts(q: jax.Array, e: jax.Array, eps2, row_block: int = 1024) -> jax.Array:
    """Per-q counts of e-points within eps (matmul form, row-blocked)."""
    nq = q.shape[0]
    ne_norm = jnp.einsum("ij,ij->i", e, e)

    pad = (-nq) % row_block
    qp = jnp.pad(q, ((0, pad), (0, 0)))
    blocks = qp.reshape(-1, row_block, q.shape[1])

    def one(qb):
        d2 = (
            jnp.einsum("ij,ij->i", qb, qb)[:, None]
            + ne_norm[None, :]
            - 2.0 * (qb @ e.T)
        )
        return jnp.sum(d2 <= eps2, axis=1, dtype=jnp.int32)

    counts = jax.lax.map(one, blocks).reshape(-1)
    return counts[:nq]


def _ring_perm(size: int) -> Sequence[Tuple[int, int]]:
    return [(j, (j + 1) % size) for j in range(size)]


def ring_scan(axes, body, carry, payload, *, num_rounds=None, overlap=False):
    """Generic BSP ring inside a ``shard_map``'d function.

    Runs ``num_rounds`` (default: the ring size) supersteps of

        carry = body(round, carry, payload)

    rotating ``payload`` -- any pytree of arrays -- one ring position
    forward (``ppermute`` to ``(j + 1) mod |p|``) between rounds.  With
    ``overlap=True`` the permute of round r+1 is *issued before* round r's
    body (the paper's Fig. 4 pipeline: transport overlaps compute; XLA
    schedules the independent ops concurrently on real hardware).

    The carry must already be device-varying over ``axes`` where vma
    tracking applies -- ``compat.pvary`` it before calling.
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    psize = compat.axis_size(axes_t)
    perm = _ring_perm(psize)
    rotate = functools.partial(
        jax.tree_util.tree_map,
        lambda x: compat.ppermute(x, axes_t, perm),
    )

    def step(r, state):
        carry, pl = state
        if overlap:
            pl_next = rotate(pl)
            carry = body(r, carry, pl)
            pl = pl_next
        else:
            carry = body(r, carry, pl)
            pl = rotate(pl)
        return carry, pl

    n = psize if num_rounds is None else num_rounds
    carry, _ = jax.lax.fori_loop(0, n, step, (carry, payload))
    return carry


def make_ring_counts_fn(mesh: Mesh, axes: AxisNames, eps: float, row_block: int = 1024):
    """Build the shard_map'd ring-join counts program for ``mesh``.

    Input: D sharded on its first axis over ``axes`` (entity partition).
    Output: per-point neighbour counts (self included), identically sharded.
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    eps2 = float(eps) ** 2

    def local(d_block):
        q = d_block

        def body(_, counts, e):
            return counts + _local_counts(q, e, eps2, row_block)

        counts0 = jnp.zeros(q.shape[0], jnp.int32)
        # the carry must be device-varying over the mesh axes on shard_map
        # versions with vma tracking; a no-op on versions without (compat)
        counts0 = compat.pvary(counts0, axes_t)
        return ring_scan(axes_t, body, counts0, q)

    spec = P(axes_t if len(axes_t) > 1 else axes_t[0])
    return jax.jit(
        compat.shard_map(local, mesh=mesh, in_specs=spec, out_specs=spec)
    )


def ring_self_join_counts(
    d: np.ndarray,
    eps: float,
    mesh: Mesh,
    axes: AxisNames = "data",
    row_block: int = 1024,
) -> np.ndarray:
    """Driver: pad to the partition size, run the ring join, unpad.

    Padding points sit at coordinate 3 + i*eps per row -- farther than any
    possible eps-match to data in [0,1] and to each other, so they contribute
    nothing to real counts and their own counts are sliced away.
    """
    pts = np.asarray(d, dtype=np.float32)
    n_pts, n_dims = pts.shape
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    psize = int(np.prod([mesh.shape[a] for a in axes_t]))
    pad = (-n_pts) % psize
    if pad:
        sentinel = 3.0 + (np.arange(pad, dtype=np.float32) * (2.0 * eps + 1.0))
        pts = np.concatenate(
            [pts, np.tile(sentinel[:, None], (1, n_dims))], axis=0
        )
    spec = P(axes_t if len(axes_t) > 1 else axes_t[0])
    arr = jax.device_put(
        jnp.asarray(pts), NamedSharding(mesh, spec)
    )
    fn = make_ring_counts_fn(mesh, axes, eps, row_block)
    counts = np.asarray(jax.device_get(fn(arr)))
    return counts[:n_pts].astype(np.int64)


def ring_comm_elements(num_points: int, num_workers: int) -> int:
    """Paper Sec. 6.3: total elements communicated = (|p| - 1) |D|."""
    return (num_workers - 1) * num_points
