"""Core datatypes for the similarity self-join.

The vocabulary follows the paper (Gowanlock & Karsin 2018):
  D        -- database of |D| points in n dimensions, coordinates in [0,1]
  eps      -- Euclidean search distance
  k        -- number of indexed dimensions (Section 4.1), 2 <= k <= n
  REORDER  -- dimensionality reordering by variance (Section 4.2)
  SORTIDU  -- sort/window on the first un-indexed dimension u (Section 4.3)
  SHORTC   -- short-circuited distance accumulation (Section 4.4),
              realised on TPU as dimension-blocked pruning (DESIGN.md #1.2)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SelfJoinConfig:
    """Configuration mirroring GPU-Join's knobs (paper Alg. 1)."""

    eps: float
    k: int = 6                   # indexed dimensions (paper uses k=6 throughout Sec. 5)
    reorder: bool = True         # REORDER (Sec. 4.2)
    sortidu: bool = True         # SORTIDU (Sec. 4.3) -> tile u-window pruning
    shortc: bool = True          # SHORTC (Sec. 4.4) -> dimension-blocked pruning
    tile_size: int = 64          # points per tile (TPU adaptation; (8,128)-friendly)
    dim_block: int = 32          # dims per SHORTC block (padded)
    sample_frac: float = 0.01    # variance / result-size sampling fraction (Sec. 4.2, 5.6)
    batch_size: int = 10**8      # b_s, result pairs per batch (paper Sec. 3.2.2)
    min_batches: int = 3         # n_b >= 3 (paper: >= 3 CUDA streams)
    use_pallas: bool = False     # evaluate tiles with the Pallas kernel (interpret on CPU)
    execution: str = "indexed"   # "indexed" | "dense" | "auto" tier dispatch;
                                 # "auto" picks by cost model (DESIGN.md #9)

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.eps < 0:
            raise ValueError(f"eps must be >= 0, got {self.eps}")
        if self.execution not in ("auto", "indexed", "dense"):
            raise ValueError(
                f"execution must be 'auto', 'indexed' or 'dense', "
                f"got {self.execution!r}"
            )


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs of the device-resident ``SelfJoinEngine`` (DESIGN.md #1.5).

    The engine evaluates the candidate tile-pair list in fixed-size chunks;
    each (mode, chunk shape) compiles to exactly one XLA program that is
    reused across chunks, across calls, and across eps values (eps is a
    traced scalar, never a compile-time constant).
    """

    count_chunk: int = 4096      # tile pairs per counts-mode device program
    pairs_chunk: int = 1024      # tile pairs per pairs-mode device program
    max_pairs: Optional[int] = None  # pairs-buffer capacity; None -> auto-size
    auto_grow: bool = True       # on auto-sized overflow, regrow to the
                                 # measured |R| (known after the pass) and retry
    pairs_headroom: float = 2.0  # auto capacity = headroom * estimated |R|
    interpret: bool = True       # run the Pallas kernel in interpret mode (CPU)

    def __post_init__(self):
        if self.count_chunk < 1 or self.pairs_chunk < 1:
            raise ValueError("chunk sizes must be >= 1")
        if self.max_pairs is not None and self.max_pairs < 0:
            raise ValueError(f"max_pairs must be >= 0, got {self.max_pairs}")


@dataclasses.dataclass
class SelfJoinStats:
    """Work counters used by the paper's evaluation (Secs. 5.5-5.7)."""

    num_points: int = 0
    num_dims: int = 0
    k: int = 0
    num_nonempty_cells: int = 0          # |G|
    num_tiles: int = 0
    num_tile_pairs_total: int = 0        # before SORTIDU window pruning
    num_tile_pairs_evaluated: int = 0    # after pruning
    num_candidates: int = 0              # point comparisons (mu in Sec. 5.6)
    num_results: int = 0                 # |R| including self-pairs
    dim_blocks_skipped: int = 0          # SHORTC effect (tile-level)
    dim_blocks_total: int = 0
    num_chunks: int = 0                  # device programs dispatched (engine)
    pairs_capacity: int = 0              # preallocated pairs buffer rows (engine)
    overflow_retries: int = 0            # auto-grow retries in pairs mode (engine)
    num_workers: int = 0                 # |p| (distributed engine)
    num_rounds: int = 0                  # ring rounds executed (= |p|)
    worker_pair_cursors: tuple = ()      # per-worker final pairs-buffer cursor
                                         # (exact pairs found, even past capacity)
    worker_max_chunk_hits: tuple = ()    # per-worker largest per-chunk hit count
                                         # (> hit_cap means the rank window clipped)
    num_device_dispatches: int = 0       # host->device chunk-program launches
                                         # per join (fused ring: exactly 1)
    num_candidates_dense: int = 0        # |Q| x |E| sum a dense ring pass would do
    comm_elements: int = 0               # ring transport volume, (|p|-1)|D| points
    execution: str = ""                  # tier that ran: "indexed" | "dense"
    cost_indexed: float = 0.0            # cost model's indexed-tier estimate
    cost_dense: float = 0.0              # cost model's dense-tier estimate

    @property
    def candidate_filter_ratio(self) -> float:
        """Fraction of the dense candidate volume the index actually evaluated."""
        if self.num_candidates_dense == 0:
            return 1.0
        return self.num_candidates / self.num_candidates_dense

    @property
    def selectivity(self) -> float:
        """S_D = (|R| - |D|) / |D|   (paper Eq. 1)."""
        if self.num_points == 0:
            return 0.0
        return (self.num_results - self.num_points) / self.num_points


@dataclasses.dataclass
class SelfJoinResult:
    """Result of a self-join.

    ``counts[i]`` is the number of points within eps of point i (including
    itself), indexed in the ORIGINAL point order.  ``pairs`` (optional) holds
    ordered (key, value) index pairs as in the paper's key/value result
    buffer; both (a,b) and (b,a) appear, as does (a,a).
    """

    counts: np.ndarray
    stats: SelfJoinStats
    pairs: Optional[np.ndarray] = None   # (num_results, 2) int32, original ids

    @property
    def total_results(self) -> int:
        return int(self.counts.sum())
