"""Cost model for hybrid dense/indexed execution dispatch (DESIGN.md #9).

The paper's optimization (i) is a filtering-vs-overhead trade-off: the grid
index prunes candidate pairs, but its tiles follow cell boundaries, so when
cells hold few points the indexed tier evaluates many partially-filled
``T x T`` tile pairs -- lane-work far above the surviving candidate count.
The dense tier re-tiles the data into *full* tiles and evaluates the
complete cross product on the MXU with no per-pair branching.  Which tier
is cheaper is a property of the grid probe stats, known before any kernel
runs:

  indexed lane-work  =  (evaluated tile pairs) x T^2 x n_pad
  dense   lane-work  =  ceil(|A|/T) x ceil(|B|/T) x T^2 x n_pad

plus, for each tier, an epilogue term proportional to its candidate volume
(the scatter/compaction work per point comparison).  Both tiers run through
the same chunk programs, so per-pair dispatch overhead cancels out of the
comparison and is not modeled.

This is the within-one-accelerator analogue of the CPU/GPU work split of
the Hybrid KNN-Join paper (arXiv:1810.04758): route the request to the
executor whose modeled work is lower, using ``stats.candidate_filter_ratio``
as the online signal the model is calibrated against.  All costs are
deterministic functions of plan shape, so a recorded ``(cost_indexed,
cost_dense)`` pair fully explains the recorded dispatch decision --
``decide(ci, cd).execution == ("dense" if cd < ci else "indexed")``.
"""
from __future__ import annotations

import dataclasses

# Relative weight of the per-candidate epilogue (count scatter-add or pairs
# compaction, one lane op per point comparison) against one MXU MAC lane op.
# Both tiers pay it over their own candidate volume; it only matters when
# n_pad is small enough that the matmul no longer dominates.
EPILOGUE_WEIGHT = 1.0

EXECUTION_MODES = ("auto", "indexed", "dense")


@dataclasses.dataclass(frozen=True)
class TierDecision:
    """One dispatch decision plus the two estimates that explain it."""

    execution: str        # tier that will run: "indexed" | "dense"
    cost_indexed: float   # modeled lane-work of the indexed tier
    cost_dense: float     # modeled lane-work of the dense tier
    forced: bool = False  # True when config pinned the tier (no comparison)


def tile_pair_lane_ops(tile_size: int, n_pad: int) -> float:
    """MXU lane ops to evaluate one T x T tile pair over n_pad dimensions."""
    return float(tile_size) * float(tile_size) * float(max(n_pad, 1))


def indexed_join_cost(
    num_tile_pairs: int,
    num_candidates: int,
    tile_size: int,
    n_pad: int,
) -> float:
    """Modeled lane-work of the indexed tier for one (self- or bipartite) join.

    ``num_tile_pairs`` is the SORTIDU-pruned candidate tile-pair count (the
    fan-out term: partially-filled tiles make it exceed the ideal
    ``candidates / T^2``); ``num_candidates`` the surviving point
    comparisons (the epilogue term).
    """
    return (
        float(num_tile_pairs) * tile_pair_lane_ops(tile_size, n_pad)
        + EPILOGUE_WEIGHT * float(num_candidates)
    )


def dense_join_cost(n_a: int, n_b: int, tile_size: int, n_pad: int) -> float:
    """Modeled lane-work of the dense tier: full-tile cross product.

    ``n_a`` / ``n_b`` are the two point-set sizes (equal for a self-join);
    the candidate volume is all ``n_a * n_b`` ordered pairs.
    """
    t = max(int(tile_size), 1)
    tiles_a = -(-max(int(n_a), 0) // t)
    tiles_b = -(-max(int(n_b), 0) // t)
    return (
        float(tiles_a) * float(tiles_b) * tile_pair_lane_ops(t, n_pad)
        + EPILOGUE_WEIGHT * float(n_a) * float(n_b)
    )


def decide(
    cost_indexed: float, cost_dense: float, mode: str = "auto"
) -> TierDecision:
    """Resolve an execution mode against the two cost estimates.

    ``"auto"`` picks the cheaper tier; ties go to the indexed tier (the
    paper's path, and the one with filtering stats).  Forced modes keep both
    estimates in the decision so stats always record what the model thought.
    """
    if mode not in EXECUTION_MODES:
        raise ValueError(
            f"unknown execution mode {mode!r}; expected one of {EXECUTION_MODES}"
        )
    if mode != "auto":
        return TierDecision(
            execution=mode, cost_indexed=float(cost_indexed),
            cost_dense=float(cost_dense), forced=True,
        )
    chosen = "dense" if cost_dense < cost_indexed else "indexed"
    return TierDecision(
        execution=chosen, cost_indexed=float(cost_indexed),
        cost_dense=float(cost_dense),
    )
