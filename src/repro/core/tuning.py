"""Selecting the number of indexed dimensions k (paper Section 5.6).

The paper's memory-operation model:

  search ops   = |D| * 3^k * log2(|G|)        (adjacent-cell binary searches)
  compare ops  = mu * (1/f)                   (sampled point comparisons)

A good k minimizes the total.  We reproduce the model exactly: for each
candidate k we build the grid, sample a fraction f of the candidate workload
for mu, and report both terms (benchmarks/bench_memops.py plots Fig. 7 from
this), plus an argmin helper.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.grid import build_grid, build_tile_plan
from repro.core.reorder import variance_reorder


@dataclasses.dataclass
class KEstimate:
    k: int
    num_cells: int                # |G|
    search_ops: float             # |D| * 3^k * log2(|G|)
    compare_ops: float            # mu / f
    total_ops: float


def estimate_k_costs(
    d: np.ndarray,
    eps: float,
    ks: Sequence[int],
    *,
    reorder: bool = True,
    sample_frac: float = 0.01,
    tile_size: int = 64,
    seed: int = 0,
) -> List[KEstimate]:
    # one generator threads through the REORDER variance sample and every
    # per-k mu sample: the k-cost estimates draw independent samples instead
    # of re-seeding default_rng(seed) inside the loop (which made every k's
    # mu sample identical to -- and correlated with -- the variance sample)
    rng = np.random.default_rng(seed)
    pts = np.asarray(d, dtype=np.float32)
    if reorder:
        pts, _ = variance_reorder(pts, sample_frac, rng=rng)
    n_pts, n = pts.shape
    out: List[KEstimate] = []
    for k in ks:
        k = int(min(k, n))
        grid = build_grid(pts, eps, k)
        g = max(grid.num_cells, 2)
        search = float(n_pts) * (3.0**k) * float(np.log2(g))
        # sample the candidate workload: a fraction of the tile pairs
        plan = build_tile_plan(grid, tile_size, sortidu=False)
        p = plan.num_pairs
        if p:
            n_sample = max(1, int(round(p * sample_frac)))
            sel = rng.choice(p, size=min(n_sample, p), replace=False)
            mu = float(
                (plan.tile_len[plan.pair_a[sel]].astype(np.int64)
                 * plan.tile_len[plan.pair_b[sel]].astype(np.int64)).sum()
            )
            compare = mu * (p / len(sel))
        else:
            compare = 0.0
        out.append(
            KEstimate(
                k=k,
                num_cells=grid.num_cells,
                search_ops=search,
                compare_ops=compare,
                total_ops=search + compare,
            )
        )
    return out


def select_k(d: np.ndarray, eps: float, ks: Sequence[int], **kw) -> int:
    """argmin-total-ops k (the paper's selection rule).

    Deterministic under ties: the smaller k wins (cheaper index build and a
    shallower 3^k adjacency), regardless of the order of ``ks``.
    """
    ests = estimate_k_costs(d, eps, ks, **kw)
    return min(ests, key=lambda e: (e.total_ops, e.k)).k
