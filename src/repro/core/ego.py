"""EGO-order CPU baseline (the comparison target, paper Sections 2.1/5.3).

The paper benchmarks against Super-EGO (Kalashnikov 2013), an epsilon-grid-
order CPU join.  We implement its structural core so Table-3-style speedup
comparisons are reproducible in-framework: points are EGO-sorted (lexico-
graphic on eps-grid coordinates of the variance-reordered dims), and each
point scans a sorted window bounded by the first dimension (|x0 - y0| <= eps
after grid alignment), short-circuiting the distance accumulation -- the two
signature Super-EGO traits the paper calls out (dimensionality reordering and
short-circuiting).  It is a faithful *algorithmic class* baseline, not a port
of the Super-EGO codebase.
"""
from __future__ import annotations

import numpy as np

from repro.core.reorder import variance_reorder


def ego_sort(d: np.ndarray, eps: float, reorder: bool = True) -> np.ndarray:
    """Return the EGO permutation: lexicographic on eps-grid coordinates."""
    pts = np.asarray(d, dtype=np.float32)
    if reorder:
        pts, _ = variance_reorder(pts)
    coords = np.floor(pts.astype(np.float64) / eps).astype(np.int64)
    return np.lexsort(tuple(coords[:, j] for j in range(coords.shape[1] - 1, -1, -1)))


def ego_join_counts(d: np.ndarray, eps: float, reorder: bool = True) -> np.ndarray:
    """Neighbour counts (self included) via the EGO sweep, original order."""
    pts_in = np.asarray(d, dtype=np.float32)
    pts = pts_in
    if reorder:
        pts, _ = variance_reorder(pts_in)
    order = ego_sort(pts, eps, reorder=False)
    s = pts[order].astype(np.float32)
    n = s.shape[0]
    eps32 = np.float32(eps)
    eps2 = eps32 * eps32
    counts_sorted = np.zeros(n, dtype=np.int64)
    x0 = s[:, 0]
    # window on dim 0: EGO order is lexicographic on grid coords, so any pair
    # within eps differs by <= 1 grid cell in dim 0 => |x0 diff| <= 2 eps in
    # the sorted-by-cell order is a safe (conservative) sweep bound.
    keys = np.floor(x0 / eps32)
    hi = np.searchsorted(keys, keys + 2, side="left")
    for i in range(n):
        j0, j1 = i + 1, int(hi[i])
        if j1 <= j0:
            counts_sorted[i] += 1  # self
            continue
        cand = s[j0:j1]
        diff = cand - s[i]
        d2 = np.einsum("ij,ij->i", diff, diff)
        m = int((d2 <= eps2).sum())
        counts_sorted[i] += m + 1          # + self
        # symmetric contribution to the matched partners
        hits = np.nonzero(d2 <= eps2)[0]
        counts_sorted[j0 + hits] += 1
    counts = np.zeros(n, dtype=np.int64)
    counts[order] = counts_sorted
    return counts
