"""Grid-indexed distributed self-join (paper Sec. 6 + DESIGN.md #7).

``DistributedSelfJoinEngine`` composes the three pieces the repo grew
separately, into the design the paper actually describes:

  * **entity partitioning** (``core/partition.py``, Sec. 6.2): the query set
    is over-decomposed into N_b batches and assigned to the |p| workers --
    round-robin by default, or cost-estimate-driven LPT (``assign_dynamic``)
    when per-batch cost estimates are requested (paper Figs. 10-11);
  * **ring rotation** (``core/distributed.py``, Sec. 6.3): the dataset is
    entity-partitioned into |p| shards E_0..E_{p-1}; in round r worker k
    holds shard (k - r) mod |p|, so after |p| BSP supersteps every query
    batch has met the whole dataset while only (|p|-1)|D| points crossed
    the wire;
  * **the grid index** (``core/grid.py`` / ``core/engine.py``, Secs. 3-4):
    each worker's local join per round runs through ``build_grid`` /
    ``build_query_tile_plan`` + the chunked tile-evaluation programs of
    ``SelfJoinEngine.count_query`` -- REORDER, SORTIDU window pruning and
    SHORTC included.

The last point is the repair this class exists for: the earlier ring driver
evaluated every (Q_k, E_j) block pair with a dense brute-force matmul,
discarding the index whose filtering is the paper's central contribution
(the distance-similarity predecessor, Gowanlock & Karsin arXiv:1803.04120,
is explicit that every worker runs the full indexed join on its batches).
``SelfJoinResult.stats`` therefore reports both ``num_candidates`` (what the
index evaluated) and ``num_candidates_dense`` (the |Q| x |E| volume the dense
ring pays): their ratio is the distributed filtering power.

Execution model: index construction is host-side (as in the paper) and the
per-round tile evaluation is device code.  Two drivers share that contract:

  * the **host-driven** BSP loop (default): the schedule re-enters Python
    between rounds, so it runs identically on 1 or 8 simulated devices and
    serves as the differential oracle for
  * the **device-fused** ring (``fused=True``): the per-(worker, round)
    query tile tables and pair lists are packed host-side into uniform
    (fleet-max-padded, sentinel-masked) arrays, the dataset shards' tile
    tables become the ``ppermute`` ring payload of
    ``core.distributed.ring_scan``, and the |p| rounds run as a
    ``fori_loop`` inside ONE compiled ``shard_map`` program -- each round
    evaluated through the same chunked count step as
    ``SelfJoinEngine.count_query`` (``engine.count_chunk_step``).  One
    trace, one dispatch per join; eps stays a traced scalar so an eps sweep
    re-executes the same program.

Fused pairs mode (DESIGN.md #7b): ``self_join_pairs(fused=True)`` runs the
same one-program ring, but the per-round chunk body is
``engine.pairs_chunk_step`` -- each worker compacts its matched (global
query id, global data id) rows into a preallocated per-worker buffer at a
running cursor that is part of the ring carry, so the cursor (and the
per-chunk max-hit watermark) survives across ``ppermute`` rounds.  The ids
are decoded inside the program through a combined (query | shard) order
table: the query half is packed per (worker, round); the shard half --
``tile_start`` and grid-sort permutation, both pre-offset to global ids --
rides the ring payload next to the shard tile tables.  Overflow accounting
is exact (the cursor advances by true hit counts even past capacity), so
the host retries the one dispatch with a widened rank window or a regrown
buffer, and the retry is rare: capacity is seeded from
``suggest_pairs_capacity`` over the fleet-max per-worker estimate.

Unequal shards from a non-divisible |D| need no sentinel padding on the
host-driven path (shard tile tables are per-shard anyway); the fused path
pads every table to the fleet-wide maximum -- padded tiles carry length 0,
padded pair-list entries sit past the per-chunk ``real`` prefix, and padded
query slots scatter to an out-of-range sentinel dropped by ``mode="drop"``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import batching as batching_mod
from repro.core import compat
from repro.core.distributed import ring_comm_elements, ring_scan
from repro.core.engine import (
    _MAX_AUTO_GROW,
    SelfJoinEngine,
    _count_chunk_program,
    _pairs_chunk_program,
    count_chunk_step,
    pairs_chunk_step,
)
from repro.core.grid import adjacent_cell_pairs, build_grid, pad_axis0
from repro.core.partition import EntityPartition, assign_dynamic, make_partition
from repro.core.reorder import variance_reorder
from repro.core.types import (
    EngineConfig,
    SelfJoinConfig,
    SelfJoinResult,
    SelfJoinStats,
)
from repro import obs
from repro.kernels import ops

AxisNames = Union[str, Tuple[str, ...]]


@dataclasses.dataclass
class DistributedKnnResult:
    """k nearest neighbours per dataset point, exact, global ids.

    ``indices[i, :]`` are the ids of the k nearest points to point i
    (self included, ties broken by id), -1 padded when k exceeds the
    dataset; ``distances`` are the matching float64 Euclidean distances,
    inf padded.  ``stats`` is the final candidate pass's
    ``SelfJoinStats``.
    """

    indices: np.ndarray      # (n, k) int64
    distances: np.ndarray    # (n, k) float64
    counts: np.ndarray       # (n,) int64 neighbour counts at eps_used
    eps_used: float          # final radius of the adaptive expansion
    eps_rounds: int          # candidate passes run (1 = no growth)
    stats: SelfJoinStats


def _mesh_workers(mesh, axes: AxisNames) -> int:
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    size = 1
    for a in axes_t:
        size *= mesh.shape[a]
    return int(size)


class DistributedSelfJoinEngine:
    """Entity-partitioned, grid-indexed ring self-join over |p| workers.

    ``num_workers`` may be given directly or derived from a ``jax`` mesh
    (``mesh=`` plus the ``axes`` the ring spans -- a 1-axis ``("data",)``
    mesh and the joint ``("pod", "data")`` mesh both work; the ring simply
    spans the product of the named axes, as in ``ring_self_join_counts``).

    ``assignment="round_robin"`` reproduces the paper's default batch
    assignment; ``assignment="dynamic"`` runs the sampling-style cost
    estimate (adjacent-cell candidate volume per batch) through the greedy
    LPT scheduler for straggler mitigation (paper Sec. 6.2).

    ``fused=True`` (requires a mesh whose ring size equals ``num_workers``)
    compiles the whole BSP schedule into one ``shard_map`` program --
    ``count()`` then costs exactly one device dispatch and an eps sweep
    re-executes the same executable (see module docstring / DESIGN.md #7a).
    The default host-driven loop is its differential oracle.
    """

    def __init__(
        self,
        d: np.ndarray,
        config: SelfJoinConfig,
        *,
        num_workers: Optional[int] = None,
        mesh=None,
        axes: AxisNames = "data",
        num_batches: Optional[int] = None,
        assignment: str = "round_robin",
        engine_config: Optional[EngineConfig] = None,
        fused: bool = False,
    ):
        if num_workers is None:
            if mesh is None:
                raise ValueError("pass num_workers or a mesh")
            num_workers = _mesh_workers(mesh, axes)
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if assignment not in ("round_robin", "dynamic"):
            raise ValueError(f"unknown assignment {assignment!r}")
        if fused:
            if mesh is None:
                raise ValueError("fused=True needs a mesh (one ring position per device)")
            if num_workers != _mesh_workers(mesh, axes):
                raise ValueError(
                    "fused=True requires num_workers == mesh ring size "
                    f"({num_workers} != {_mesh_workers(mesh, axes)})"
                )

        self.config = config
        self.engine_config = engine_config
        self.mesh = mesh
        self.axes = axes
        self._pts = np.ascontiguousarray(np.asarray(d, dtype=np.float32))
        self.num_points, self.num_dims = self._pts.shape
        self.num_workers = int(num_workers)

        # dataset shards E_j: contiguous entity partition, unequal tails ok
        self.shard_bounds = np.linspace(
            0, self.num_points, self.num_workers + 1
        ).round().astype(np.int64)
        self.shards: List[SelfJoinEngine] = [
            SelfJoinEngine(
                self._pts[self.shard_bounds[j]:self.shard_bounds[j + 1]],
                config,
                engine_config,
            )
            for j in range(self.num_workers)
        ]

        # query batches Q_l, over-decomposed (N_b defaults to 4|p|)
        n_b = num_batches if num_batches is not None else 4 * self.num_workers
        self.partition: EntityPartition = make_partition(
            self.num_points, self.num_workers, n_b
        )
        self._batch_costs: Optional[np.ndarray] = None
        if assignment == "dynamic":
            self.partition.assignment = assign_dynamic(
                self.estimate_batch_costs(), self.num_workers
            )
        self.assignment = assignment

        # fused-ring state (built lazily on the first fused count)
        self.fused = bool(fused)
        self._fused_pack = None       # packed tables + compiled program
        self.fused_traces = 0         # times the fused count program was traced
        self.fused_executions = 0     # times it was executed
        self.fused_pairs_traces = 0      # fused pairs-program traces
        self.fused_pairs_executions = 0  # fused pairs-program executions

    # -- partitioning -----------------------------------------------------

    def worker_query_index(self, worker: int) -> np.ndarray:
        """Original-order indices of all query points owned by ``worker``."""
        ranges = [
            np.arange(*self.partition.query_range(b), dtype=np.int64)
            for b in self.partition.batches_of(worker)
        ]
        if not ranges:
            return np.zeros(0, np.int64)
        return np.concatenate(ranges)

    def estimate_batch_costs(self) -> np.ndarray:
        """Per-batch candidate-volume estimates from one global grid probe.

        The cost of joining a batch is dominated by its candidate count; the
        grid gives it cheaply: for every point, the total population of its
        3^k adjacent non-empty cells.  One ``build_grid`` over the full
        (reordered) dataset plus one vectorized adjacency probe -- the same
        sampling-pass flavour the paper uses to drive its scheduler.
        """
        if self._batch_costs is not None:
            return self._batch_costs
        costs = np.zeros(self.partition.num_batches, dtype=np.float64)
        if self.num_points == 0:
            self._batch_costs = costs
            return costs
        work = self._pts
        if self.config.reorder:
            work, _ = variance_reorder(self._pts, self.config.sample_frac)
        grid = build_grid(work, self.config.eps, self.config.k)
        ca, cb = adjacent_cell_pairs(grid)
        cell_cand = np.zeros(grid.num_cells, dtype=np.float64)
        np.add.at(cell_cand, ca, grid.cell_count[cb].astype(np.float64))
        cell_of_point = np.repeat(
            np.arange(grid.num_cells, dtype=np.int64), grid.cell_count
        )
        per_point = np.empty(self.num_points, dtype=np.float64)
        per_point[grid.point_order] = cell_cand[cell_of_point]
        for b in range(self.partition.num_batches):
            lo, hi = self.partition.query_range(b)
            costs[b] = per_point[lo:hi].sum()
        self._batch_costs = costs
        return costs

    def worker_loads(self) -> np.ndarray:
        """Estimated candidate load per worker under the current assignment."""
        costs = self.estimate_batch_costs()
        loads = np.zeros(self.num_workers, dtype=np.float64)
        for b in range(self.partition.num_batches):
            loads[self.partition.assignment[b]] += costs[b]
        return loads

    # -- ring schedule ----------------------------------------------------

    def ring_schedule(self) -> List[List[Tuple[int, int]]]:
        """Round r -> [(worker k, shard it holds)]: shard (k - r) mod |p|."""
        p = self.num_workers
        return [[(k, (k - r) % p) for k in range(p)] for r in range(p)]

    def comm_elements(self) -> int:
        """Ring transport volume in points: (|p| - 1) |D| (paper Sec. 6.3)."""
        return ring_comm_elements(self.num_points, self.num_workers)

    # -- fused device ring (DESIGN.md #7 addendum) -------------------------

    def _pack_fused(self, eps: float):
        """Pack the fused ring's device tables and compile its program.

        Everything host-side happens here, once per index radius: the |p|^2
        bipartite query plans (worker k's batches binned into shard j's
        grid, j = (k - r) mod |p| for round r), padded to fleet-wide maxima
        so one trace fits every ring position, plus the padded shard tile
        tables that form the rotating payload.  eps is NOT baked in -- the
        program takes it as a traced scalar, so a sweep at or below the
        packed radius reuses both the pack and the compiled executable.
        """
        with obs.span(
            "ring.pack", "plan", workers=self.num_workers, eps=float(eps)
        ):
            return self._pack_fused_impl(eps)

    def _pack_fused_impl(self, eps: float):
        p = self.num_workers
        cfg = self.config
        eng = self.engine_config or EngineConfig()
        t = cfg.tile_size
        n_pad = self.shards[0].snapshot.n_pad

        q_index = [self.worker_query_index(k) for k in range(p)]
        q_pts = [self._pts[idx] for idx in q_index]
        nq = [int(idx.size) for idx in q_index]
        max_nq = max(max(nq), 1)

        # |p|^2 host-side bipartite plans: worker k meets shard (k - r) % p
        # in round r (None where either side is empty -> fully masked round)
        qplans = []
        for k in range(p):
            row = []
            for r in range(p):
                if nq[k]:
                    with obs.span(
                        "ring.pack.plan", "ring",
                        worker=k, round=r, nq=nq[k],
                    ):
                        row.append(
                            self.shards[(k - r) % p].build_query_plan(
                                q_pts[k], eps
                            )
                        )
                else:
                    row.append(None)
            qplans.append(row)
        flat = [qp for row in qplans for qp in row if qp is not None]
        max_qt = max(max((qp.num_q_tiles for qp in flat), default=0), 1)
        max_dt = max(max((e.snapshot.plan.num_tiles if e.snapshot.plan else 0
                  for e in self.shards), default=0), 1)
        max_pr = max((qp.num_pairs for qp in flat), default=0)
        chunk = max(1, min(eng.count_chunk, max(max_pr, 1)))
        n_chunks = max(-(-max_pr // chunk), 1)
        chunk_p = max(1, min(eng.pairs_chunk, max(max_pr, 1)))
        n_chunks_p = max(-(-max_pr // chunk_p), 1)
        shard_sizes = np.diff(self.shard_bounds)
        max_sn = max(int(shard_sizes.max()) if shard_sizes.size else 0, 1)

        qt = np.zeros((p, p, max_qt, t, n_pad), np.float32)
        qstart = np.zeros((p, p, max_qt), np.int32)
        qlen = np.zeros((p, p, max_qt), np.int32)
        qord = np.full((p, p, max_nq), max_nq, np.int32)   # sentinel: dropped
        pq = np.zeros((p, p, n_chunks, chunk), np.int32)
        pd = np.zeros((p, p, n_chunks, chunk), np.int32)
        real = np.zeros((p, p, n_chunks), np.int32)
        dt = np.zeros((p, max_dt, t, n_pad), np.float32)
        dlen = np.zeros((p, max_dt), np.int32)
        # pairs-mode tables (DESIGN.md #7b): the same plans re-chunked at the
        # pairs granularity, plus the global-id decode tables -- the query
        # half (qog) indexed per (worker, round), the shard half (dstart,
        # dord, already offset to global ids) riding the ring payload
        qog = np.zeros((p, p, max_nq), np.int32)
        pqp = np.zeros((p, p, n_chunks_p, chunk_p), np.int32)
        pdp = np.zeros((p, p, n_chunks_p, chunk_p), np.int32)
        realp = np.zeros((p, p, n_chunks_p), np.int32)
        dstart = np.zeros((p, max_dt), np.int32)
        dord = np.zeros((p, max_sn), np.int32)

        for j, e in enumerate(self.shards):
            dt[j], dlen[j] = e.snapshot.packed_tile_table(max_dt)
            if e.snapshot.plan is not None:
                dstart[j] = pad_axis0(
                    e.snapshot.plan.tile_start.astype(np.int32), max_dt
                )
            if e.snapshot.grid is not None:
                dord[j, : shard_sizes[j]] = (
                    self.shard_bounds[j] + e.snapshot.grid.point_order
                ).astype(np.int32)

        stats_pairs_total = stats_pairs_eval = stats_candidates = 0
        for k in range(p):
            for r in range(p):
                qp = qplans[k][r]
                if qp is None:
                    continue
                stats_pairs_total += qp.num_tile_pairs_total
                stats_pairs_eval += qp.num_pairs
                stats_candidates += qp.num_candidates
                if qp.num_q_tiles:
                    tiles_kr, len_kr = ops.make_tiles(
                        qp.q_sorted, qp.q_tile_start, qp.q_tile_len, t, cfg.dim_block
                    )
                    qt[k, r, : tiles_kr.shape[0]] = tiles_kr
                    qlen[k, r] = pad_axis0(len_kr, max_qt)
                    qstart[k, r] = pad_axis0(qp.q_tile_start, max_qt)
                qord[k, r, : nq[k]] = qp.q_order.astype(np.int32)
                # pairs decode: q-sorted position -> GLOBAL query id
                qog[k, r, : nq[k]] = q_index[k][qp.q_order].astype(np.int32)
                if qp.num_pairs:
                    pq[k, r].reshape(-1)[: qp.num_pairs] = qp.pair_q
                    # B side indexes the concatenated [query | shard] table
                    pd[k, r].reshape(-1)[: qp.num_pairs] = qp.pair_d + max_qt
                    real[k, r] = np.clip(
                        qp.num_pairs - np.arange(n_chunks) * chunk, 0, chunk
                    ).astype(np.int32)
                    pqp[k, r].reshape(-1)[: qp.num_pairs] = qp.pair_q
                    pdp[k, r].reshape(-1)[: qp.num_pairs] = qp.pair_d + max_qt
                    realp[k, r] = np.clip(
                        qp.num_pairs - np.arange(n_chunks_p) * chunk_p,
                        0, chunk_p,
                    ).astype(np.int32)

        axes_t = (self.axes,) if isinstance(self.axes, str) else tuple(self.axes)
        ax = axes_t if len(axes_t) > 1 else axes_t[0]
        backend = "pallas" if cfg.use_pallas else "jnp"
        interpret = eng.interpret
        engine_self = self

        def local(qt, qstart, qlen, qord, pq, pd, real, dt, dlen, eps_in):
            engine_self.fused_traces += 1  # traced once; executions replay it
            obs.event("ring.trace", "compile", program="fused_count")
            qt, qstart, qlen, qord = qt[0], qstart[0], qlen[0], qord[0]
            pq, pd, real = pq[0], pd[0], real[0]
            dt, dlen = dt[0], dlen[0]

            def round_body(r, counts_local, payload):
                d_tiles, d_len = payload
                tiles = jnp.concatenate([qt[r], d_tiles], axis=0)
                tlen = jnp.concatenate([qlen[r], d_len])
                # B-side starts are never read (only pair_a rows scatter)
                tstart = jnp.concatenate([qstart[r], jnp.zeros_like(d_len)])

                def chunk_body(c, counts_sorted):
                    counts_sorted, _ = count_chunk_step(
                        counts_sorted, jnp.zeros((), jnp.int32),
                        tiles, tlen, tstart,
                        pq[r, c], pd[r, c], real[r, c], eps_in,
                        dim_block=cfg.dim_block, shortc=cfg.shortc,
                        backend=backend, interpret=interpret,
                    )
                    return counts_sorted

                counts_sorted = jax.lax.fori_loop(
                    0, n_chunks, chunk_body, jnp.zeros(max_nq, jnp.int32)
                )
                # per-round q_order: q-sorted position -> worker-local slot
                return counts_local.at[qord[r]].add(counts_sorted, mode="drop")

            counts0 = compat.pvary(jnp.zeros(max_nq, jnp.int32), axes_t)
            counts = ring_scan(axes_t, round_body, counts0, (dt, dlen))
            return counts[None]

        def pspec(arr):
            return P(ax, *([None] * (arr.ndim - 1)))

        # pairs capacity seeding: hit-rate sample on the heaviest (k, r)
        # block (small explicit chunk -- the default 4096-pair padding would
        # dwarf the sample), scaled by each worker's total candidate volume
        best_kr = None
        for k in range(p):
            for r in range(p):
                qp = qplans[k][r]
                if qp is not None and qp.num_pairs and (
                    best_kr is None
                    or qp.num_pairs > qplans[best_kr[0]][best_kr[1]].num_pairs
                ):
                    best_kr = (k, r)
        hit_rate = 0.0
        if best_kr is not None:
            k0, r0 = best_kr
            with obs.span(
                "ring.pack.sample", "plan", worker=k0, round=r0
            ) as _sp:
                qp = qplans[k0][r0]
                j0 = (k0 - r0) % p
                n_s = min(qp.num_pairs, 512)
                rng = np.random.default_rng(0)
                sel = (
                    rng.choice(qp.num_pairs, size=n_s, replace=False)
                    if qp.num_pairs > n_s else np.arange(n_s)
                )
                len_c = np.concatenate([qlen[k0, r0], dlen[j0]])
                counts_s, _ = ops.tile_counts(
                    np.concatenate([qt[k0, r0], dt[j0]], axis=0), len_c,
                    qp.pair_q[sel], qp.pair_d[sel] + max_qt,
                    eps=eps, dim_block=cfg.dim_block, shortc=cfg.shortc,
                    backend=backend, chunk=min(n_s, 512), interpret=interpret,
                )
                cand_s = float(
                    (len_c[qp.pair_q[sel]].astype(np.float64)
                     * len_c[qp.pair_d[sel] + max_qt]).sum()
                )
                hit_rate = float(counts_s.sum()) / max(cand_s, 1.0)
                _sp.set(hit_rate=hit_rate, sampled_pairs=int(n_s))
        pairs_est = [
            int(np.ceil(hit_rate * sum(
                qp.num_candidates for qp in qplans[k] if qp is not None
            )))
            for k in range(p)
        ]
        pairs_cap = batching_mod.suggest_pairs_capacity(
            max(pairs_est, default=0), eng.pairs_headroom
        )

        # tables go device-resident (with their ring sharding) at pack time:
        # repeat joins and eps sweeps then transfer only the eps scalar
        args = tuple(
            jax.device_put(a, NamedSharding(self.mesh, pspec(a)))
            for a in (qt, qstart, qlen, qord, pq, pd, real, dt, dlen)
        )
        fn = jax.jit(
            compat.shard_map(
                local,
                mesh=self.mesh,
                in_specs=tuple(pspec(a) for a in args) + (P(),),
                out_specs=P(ax, None),
                # pallas_call has no replication rule; the program's outputs
                # are device-varying by construction, so the check adds
                # nothing here
                check_rep=not cfg.use_pallas,
            )
        )

        pairs_extra = tuple(
            jax.device_put(a, NamedSharding(self.mesh, pspec(a)))
            for a in (qog, pqp, pdp, realp, dstart, dord)
        )
        pairs_args = (
            args[0], args[1], args[2],                               # qt qstart qlen
            pairs_extra[0], pairs_extra[1], pairs_extra[2], pairs_extra[3],
            args[7], args[8],                                        # dt dlen
            pairs_extra[4], pairs_extra[5],                          # dstart dord
        )
        pairs_in_specs = tuple(pspec(a) for a in pairs_args) + (P(),)

        def make_pairs_fn(cap: int, hit_cap: int):
            """One-program fused pairs ring, compiled for (cap, hit_cap).

            Same transport as the count program, but the ring carry is the
            per-worker (buffer, cursor, max-chunk-hits) triple of
            ``pairs_chunk_step`` and the payload additionally rotates the
            shard-side decode tables.  Programs are cached per (cap,
            hit_cap) by the caller; a non-overflowing join uses exactly one.
            """

            def local_pairs(qt, qstart, qlen, qog, pqp, pdp, realp,
                            dt, dlen, dstart, dord, eps_in):
                engine_self.fused_pairs_traces += 1
                obs.event(
                    "ring.trace", "compile", program="fused_pairs",
                    cap=cap, hit_cap=hit_cap,
                )
                qt, qstart, qlen, qog = qt[0], qstart[0], qlen[0], qog[0]
                pqp, pdp, realp = pqp[0], pdp[0], realp[0]
                dt, dlen, dstart, dord = dt[0], dlen[0], dstart[0], dord[0]

                def round_body(r, carry, payload):
                    d_tiles, d_len, d_start, d_ord = payload
                    tiles = jnp.concatenate([qt[r], d_tiles], axis=0)
                    tlen = jnp.concatenate([qlen[r], d_len])
                    # combined (query | shard) position space: B-side starts
                    # offset past the query slots, ids decode through the
                    # concatenated order table to GLOBAL point ids
                    tstart = jnp.concatenate([qstart[r], d_start + max_nq])
                    order = jnp.concatenate([qog[r], d_ord])

                    def chunk_body(c, carry2):
                        return pairs_chunk_step(
                            *carry2, tiles, tlen, tstart, order,
                            pqp[r, c], pdp[r, c], realp[r, c], eps_in,
                            hit_cap=hit_cap, dim_block=cfg.dim_block,
                            backend=backend, interpret=interpret,
                        )

                    return jax.lax.fori_loop(0, n_chunks_p, chunk_body, carry)

                carry0 = (
                    compat.pvary(
                        jnp.zeros((cap + hit_cap, 2), jnp.int32), axes_t
                    ),
                    compat.pvary(jnp.zeros((), jnp.int32), axes_t),
                    compat.pvary(jnp.zeros((), jnp.int32), axes_t),
                )
                buf, off, mh = ring_scan(
                    axes_t, round_body, carry0, (dt, dlen, dstart, dord)
                )
                return buf[None], off[None], mh[None]

            return jax.jit(
                compat.shard_map(
                    local_pairs,
                    mesh=self.mesh,
                    in_specs=pairs_in_specs,
                    out_specs=(P(ax, None, None), P(ax), P(ax)),
                    check_rep=not cfg.use_pallas,
                )
            )

        self._fused_pack = dict(
            eps=float(eps), fn=fn, args=args,
            q_index=q_index, nq=nq, n_chunks=n_chunks,
            stats=(stats_pairs_total, stats_pairs_eval, stats_candidates),
            pairs_args=pairs_args, make_pairs_fn=make_pairs_fn,
            pairs_fns={},                       # (cap, hit_cap) -> compiled fn
            pairs_cap=pairs_cap, pairs_est=pairs_est,
            n_chunks_p=n_chunks_p,
            pairs_flat_per_chunk=chunk_p * t * t,
            # expected hits in one full pairs chunk, for rank-window seeding
            pairs_hit_est=int(np.ceil(hit_rate * chunk_p * t * t)),
        )
        return self._fused_pack

    def _count_fused(self, eps: float) -> SelfJoinResult:
        """One-dispatch fused ring count (counts == host-driven ``count()``)."""
        pack = self._fused_pack
        if pack is None or eps > pack["eps"]:
            pack = self._pack_fused(max(eps, self.config.eps))
        with obs.span(
            "ring.fused.count", "dispatch",
            workers=self.num_workers, rounds=self.num_workers, eps=eps,
        ):
            out = np.asarray(
                jax.device_get(pack["fn"](*pack["args"], jnp.float32(eps)))
            )
        self.fused_executions += 1
        counts = np.zeros(self.num_points, dtype=np.int64)
        for k in range(self.num_workers):
            counts[pack["q_index"][k]] = out[k, : pack["nq"][k]]
        pairs_total, pairs_eval, candidates = pack["stats"]
        shard_sizes = np.diff(self.shard_bounds)
        stats = SelfJoinStats(
            num_points=self.num_points,
            num_dims=self.num_dims,
            k=min(self.config.k, self.num_dims),
            num_workers=self.num_workers,
            num_rounds=self.num_workers,
            comm_elements=self.comm_elements(),
            num_tile_pairs_total=pairs_total,
            num_tile_pairs_evaluated=pairs_eval,
            num_candidates=candidates,
            num_chunks=self.num_workers * pack["n_chunks"],
            num_device_dispatches=1,
            num_candidates_dense=int(
                sum(
                    pack["nq"][k] * shard_sizes[j]
                    for r, sched in enumerate(self.ring_schedule())
                    for k, j in sched
                )
            ),
            num_results=int(counts.sum()),
        )
        stats.num_tiles = sum(
            e.snapshot.plan.num_tiles for e in self.shards if e.snapshot.plan
        )
        stats.num_nonempty_cells = sum(
            e.snapshot.grid.num_cells for e in self.shards if e.snapshot.grid
        )
        obs.mirror_selfjoin_stats(stats, path="ring_fused", mode="count")
        return SelfJoinResult(counts=counts, stats=stats)

    def _index_stats(self, stats: SelfJoinStats) -> SelfJoinStats:
        stats.num_tiles = sum(
            e.snapshot.plan.num_tiles for e in self.shards if e.snapshot.plan
        )
        stats.num_nonempty_cells = sum(
            e.snapshot.grid.num_cells for e in self.shards if e.snapshot.grid
        )
        return stats

    def _dense_candidates(self, nq: List[int]) -> int:
        shard_sizes = np.diff(self.shard_bounds)
        return int(
            sum(
                nq[k] * shard_sizes[j]
                for sched in self.ring_schedule()
                for k, j in sched
            )
        )

    def _pairs_fused(
        self, eps: float, max_pairs: Optional[int] = None
    ) -> SelfJoinResult:
        """One-dispatch fused ring pairs join (DESIGN.md #7b).

        Every worker fills its own (capacity + hit_cap, 2) buffer inside the
        single ``shard_map`` program; the per-worker cursors and max-chunk
        hit watermarks come back with the buffers, so overflow is detected
        exactly on the host.  The retry ladder mirrors
        ``SelfJoinEngine.pairs``: widen the per-chunk rank window first
        (compaction correctness), then regrow the buffer to the measured
        fleet-max |R_k| (auto mode only; an explicit ``max_pairs`` raises).
        Each (cap, hit_cap) compiles once and is cached in the pack, so a
        non-overflowing join costs one trace and one dispatch.
        """
        pack = self._fused_pack
        if pack is None or eps > pack["eps"]:
            pack = self._pack_fused(max(eps, self.config.eps))
        eng = self.engine_config or EngineConfig()
        p = self.num_workers
        explicit = max_pairs if max_pairs is not None else eng.max_pairs
        auto = explicit is None
        cap = pack["pairs_cap"] if auto else int(explicit)
        flat_per_chunk = pack["pairs_flat_per_chunk"]
        # rank-window seed: 4x the sampled expected per-chunk hits absorbs
        # chunk-to-chunk skew, so the first join rarely needs the widen retry
        hit_cap = min(
            flat_per_chunk,
            max(4096, -(-4 * pack["pairs_hit_est"] // 1024) * 1024),
        )
        warm = pack.get("pairs_warm")
        if warm is not None:  # converged settings of an earlier join: 0 retries
            hit_cap = max(hit_cap, warm[1])
            if auto:
                cap = max(cap, warm[0])

        retries = 0
        while True:
            key = (cap, hit_cap)
            fn = pack["pairs_fns"].get(key)
            if fn is None:
                fn = pack["make_pairs_fn"](cap, hit_cap)
                pack["pairs_fns"][key] = fn
            with obs.span(
                "ring.fused.pairs", "dispatch",
                workers=p, rounds=p, eps=eps, attempt=retries,
                cap=cap, hit_cap=hit_cap,
            ):
                buf, off, mh = fn(*pack["pairs_args"], jnp.float32(eps))
            self.fused_pairs_executions += 1
            off_np = np.asarray(jax.device_get(off)).astype(np.int64)
            mh_np = np.asarray(jax.device_get(mh)).astype(np.int64)
            max_off = int(off_np.max()) if off_np.size else 0
            max_mh = int(mh_np.max()) if mh_np.size else 0
            # exact totals are known after the one dispatch, so each
            # overflow kind resolves in one retry (same ladder as
            # SelfJoinEngine.pairs)
            if max_mh > hit_cap:
                if retries >= _MAX_AUTO_GROW:
                    raise RuntimeError(
                        f"fused pairs rank window did not converge "
                        f"(max chunk hits {max_mh} > hit_cap {hit_cap})"
                    )
                obs.event(
                    "ring.pairs.retry", "retry", kind="hit_cap",
                    max_hits=max_mh, hit_cap=hit_cap,
                )
                hit_cap = min(flat_per_chunk, -(-max_mh // 1024) * 1024)
                retries += 1
                continue
            if max_off > cap:
                if auto and eng.auto_grow and retries < _MAX_AUTO_GROW:
                    obs.event(
                        "ring.pairs.retry", "retry", kind="capacity",
                        num=max_off, cap=cap,
                    )
                    cap = batching_mod.suggest_pairs_capacity(max_off, 1.0)
                    retries += 1
                    continue
                raise RuntimeError(
                    f"fused ring worker found {max_off} pairs, exceeding "
                    f"max_pairs={cap}; raise the cap or lower eps"
                )
            if auto:
                pack["pairs_warm"] = (cap, hit_cap)
            break

        buf_np = np.asarray(jax.device_get(buf))
        parts = [buf_np[k, : off_np[k]] for k in range(p)]
        pairs = (
            np.concatenate(parts) if parts else np.zeros((0, 2), np.int32)
        ).astype(np.int32)
        counts = np.zeros(self.num_points, dtype=np.int64)
        if pairs.shape[0]:
            counts = np.bincount(
                pairs[:, 0], minlength=self.num_points
            ).astype(np.int64)
        pairs_total, pairs_eval, candidates = pack["stats"]
        stats = SelfJoinStats(
            num_points=self.num_points,
            num_dims=self.num_dims,
            k=min(self.config.k, self.num_dims),
            num_workers=p,
            num_rounds=p,
            comm_elements=self.comm_elements(),
            num_tile_pairs_total=pairs_total,
            num_tile_pairs_evaluated=pairs_eval,
            num_candidates=candidates,
            num_chunks=p * pack["n_chunks_p"],
            num_device_dispatches=1 + retries,
            pairs_capacity=cap,
            overflow_retries=retries,
            worker_pair_cursors=tuple(int(x) for x in off_np),
            worker_max_chunk_hits=tuple(int(x) for x in mh_np),
            num_candidates_dense=self._dense_candidates(pack["nq"]),
            num_results=int(pairs.shape[0]),
        )
        obs.mirror_selfjoin_stats(stats, path="ring_fused", mode="pairs")
        return SelfJoinResult(
            counts=counts, stats=self._index_stats(stats), pairs=pairs
        )

    def _block_pairs(
        self,
        k: int,
        j: int,
        q_pts_k: np.ndarray,
        eps: float,
        eng: EngineConfig,
        stats: SelfJoinStats,
    ) -> np.ndarray:
        """Exact (global query id, global data id) pairs of one (Q_k, E_j)
        block, via the host-driven count-then-pairs pattern of the serving
        tier: the count pass sizes the buffer exactly, so the pairs pass
        never overflows (only the per-chunk rank window may widen)."""
        e = self.shards[j]
        tab = e.prepare_query(q_pts_k, eps)
        if tab is None:
            return np.zeros((0, 2), np.int64)
        cfg = self.config
        backend = ops.backend_name(tab.execution, cfg.use_pallas)
        shortc = cfg.shortc and tab.execution == "indexed"

        counts_sorted = jnp.zeros(tab.n_slots, jnp.int32)
        skipped = jnp.zeros((), jnp.int32)
        for pa, pb, real in tab.chunks(eng.count_chunk):
            with obs.span(
                "ring.block.count.chunk", "dispatch", worker=k, shard=j
            ):
                counts_sorted, skipped = _count_chunk_program(
                    counts_sorted, skipped,
                    tab.tiles, tab.tile_len, tab.tile_start,
                    pa, pb, real, jnp.float32(eps),
                    dim_block=cfg.dim_block, shortc=shortc,
                    backend=backend, interpret=eng.interpret,
                )
            stats.num_device_dispatches += 1
        total = int(np.asarray(counts_sorted.sum()))

        t = cfg.tile_size
        flat_per_chunk = eng.pairs_chunk * t * t
        hit_cap = min(flat_per_chunk, 4096)
        cap = 1 << (max(total, 1) - 1).bit_length()  # pow2: bounded trace keys
        for _ in range(_MAX_AUTO_GROW + 1):
            buf = jnp.zeros((cap + hit_cap, 2), jnp.int32)
            offset = jnp.zeros((), jnp.int32)
            max_hits = jnp.zeros((), jnp.int32)
            for pa, pb, real in tab.chunks(eng.pairs_chunk):
                with obs.span(
                    "ring.block.pairs.chunk", "dispatch", worker=k, shard=j
                ):
                    buf, offset, max_hits = _pairs_chunk_program(
                        buf, offset, max_hits,
                        tab.tiles, tab.tile_len, tab.tile_start, tab.order,
                        pa, pb, real, jnp.float32(eps),
                        hit_cap=hit_cap, dim_block=cfg.dim_block,
                        backend=backend, interpret=eng.interpret,
                    )
                stats.num_device_dispatches += 1
                stats.num_chunks += 1
            if int(max_hits) <= hit_cap:
                break
            hit_cap = min(
                flat_per_chunk, 1 << (int(max_hits) - 1).bit_length()
            )
        num = int(offset)
        if num != total:
            raise RuntimeError(
                f"block ({k}, {j}) pairs pass found {num} pairs but the "
                f"count pass said {total}"
            )
        stats.num_tile_pairs_total += tab.qplan.num_tile_pairs_total
        stats.num_tile_pairs_evaluated += tab.num_pairs
        stats.num_candidates += tab.num_candidates

        blk = np.asarray(buf[:num]).astype(np.int64)
        if num:
            # order decodes A-side to q-row ids, B-side to shard-local ids
            blk[:, 0] = self.worker_query_index(k)[blk[:, 0]]
            blk[:, 1] += self.shard_bounds[j]
        return blk

    def _pairs_host(
        self, eps: float, max_pairs: Optional[int] = None
    ) -> SelfJoinResult:
        """Host-driven BSP pairs join: the fused path's differential oracle.

        Same |p|-round schedule as ``count()``, each (worker, shard) block
        materialized through the chunked pairs program and decoded to
        global ids on the host.  Exact by construction (count-first
        sizing); an explicit ``max_pairs`` below the true |R| raises, for
        API symmetry with the fused path.
        """
        eng = self.engine_config or EngineConfig()
        stats = SelfJoinStats(
            num_points=self.num_points,
            num_dims=self.num_dims,
            k=min(self.config.k, self.num_dims),
            num_workers=self.num_workers,
            comm_elements=self.comm_elements(),
        )
        q_index = [self.worker_query_index(k) for k in range(self.num_workers)]
        q_points = [self._pts[idx] for idx in q_index]
        blocks = []
        for r, round_sched in enumerate(self.ring_schedule()):
            with obs.span(
                "ring.round", "ring",
                round=r, workers=self.num_workers, mode="pairs",
            ):
                for k, j in round_sched:
                    if q_index[k].size == 0:
                        continue
                    blocks.append(
                        self._block_pairs(k, j, q_points[k], eps, eng, stats)
                    )
            stats.num_rounds += 1
        pairs = (
            np.concatenate(blocks) if blocks else np.zeros((0, 2), np.int64)
        ).astype(np.int32)
        explicit = max_pairs if max_pairs is not None else eng.max_pairs
        if explicit is not None and pairs.shape[0] > int(explicit):
            raise RuntimeError(
                f"result exceeded max_pairs={int(explicit)}; raise the cap "
                f"or lower eps"
            )
        counts = np.zeros(self.num_points, dtype=np.int64)
        if pairs.shape[0]:
            counts = np.bincount(
                pairs[:, 0], minlength=self.num_points
            ).astype(np.int64)
        stats.num_results = int(pairs.shape[0])
        stats.num_candidates_dense = self._dense_candidates(
            [idx.size for idx in q_index]
        )
        obs.mirror_selfjoin_stats(stats, path="ring_host", mode="pairs")
        return SelfJoinResult(
            counts=counts, stats=self._index_stats(stats), pairs=pairs
        )

    # -- queries ----------------------------------------------------------

    def count(self, eps: Optional[float] = None) -> SelfJoinResult:
        """Per-point neighbour counts (self included), original order.

        Executes the |p|-round BSP schedule: in round r every worker joins
        its query batches against the shard it currently holds, through that
        shard's grid index (``SelfJoinEngine.count_query``).  Counts
        accumulate across rounds; after |p| rounds each query point has met
        every shard exactly once, so the result equals the single-device
        ``SelfJoinEngine.count()`` and the brute-force oracle.

        With ``fused=True`` the same schedule runs as one compiled
        ``shard_map`` program (``_count_fused``); this host-driven loop is
        its differential oracle.
        """
        eps = self.config.eps if eps is None else float(eps)
        if self.fused and self.num_points:
            return self._count_fused(eps)
        counts = np.zeros(self.num_points, dtype=np.int64)
        stats = SelfJoinStats(
            num_points=self.num_points,
            num_dims=self.num_dims,
            k=min(self.config.k, self.num_dims),
            num_workers=self.num_workers,
            comm_elements=self.comm_elements(),
        )
        q_index = [self.worker_query_index(k) for k in range(self.num_workers)]
        q_points = [self._pts[idx] for idx in q_index]
        shard_sizes = np.diff(self.shard_bounds)
        for r, round_sched in enumerate(self.ring_schedule()):
            with obs.span(
                "ring.round", "ring",
                round=r, workers=self.num_workers, mode="count",
            ):
                for k, j in round_sched:
                    if q_index[k].size == 0:
                        continue
                    res = self.shards[j].count_query(q_points[k], eps)
                    counts[q_index[k]] += res.counts
                    s = res.stats
                    stats.num_tile_pairs_total += s.num_tile_pairs_total
                    stats.num_tile_pairs_evaluated += s.num_tile_pairs_evaluated
                    stats.num_candidates += s.num_candidates
                    stats.num_chunks += s.num_chunks
                    stats.num_device_dispatches += s.num_chunks
                    stats.dim_blocks_skipped += s.dim_blocks_skipped
                    stats.dim_blocks_total += s.dim_blocks_total
                    stats.num_candidates_dense += int(
                        q_index[k].size * shard_sizes[j]
                    )
            stats.num_rounds += 1
        stats.num_tiles = sum(
            e.snapshot.plan.num_tiles for e in self.shards if e.snapshot.plan
        )
        stats.num_nonempty_cells = sum(
            e.snapshot.grid.num_cells for e in self.shards if e.snapshot.grid
        )
        stats.num_results = int(counts.sum())
        obs.mirror_selfjoin_stats(stats, path="ring_host", mode="count")
        return SelfJoinResult(counts=counts, stats=stats)

    def self_join_pairs(
        self,
        eps: Optional[float] = None,
        max_pairs: Optional[int] = None,
        fused: Optional[bool] = None,
    ) -> SelfJoinResult:
        """Counts plus the materialized (a, b) pair list, GLOBAL ids.

        Distributed analogue of ``SelfJoinEngine.pairs``: both (a, b) and
        (b, a) appear, as does (a, a); ``counts`` equals ``count()``.
        ``fused=None`` follows the engine's construction mode; ``fused=
        False`` forces the host-driven BSP loop (the differential oracle)
        even on a fused engine; ``fused=True`` requires one.  The fused
        path is one device dispatch per non-overflowing join
        (``_pairs_fused``); the host path is |p|^2 blocks of
        count-then-pairs dispatches.  Pair order differs between the two
        paths (per-worker ring order vs schedule order) -- the pair SET is
        identical.
        """
        eps = self.config.eps if eps is None else float(eps)
        use_fused = self.fused if fused is None else bool(fused)
        if use_fused and not self.fused:
            raise ValueError(
                "fused=True requires an engine constructed with fused=True "
                "(a mesh-backed ring)"
            )
        if use_fused and self.num_points:
            return self._pairs_fused(eps, max_pairs)
        return self._pairs_host(eps, max_pairs)

    def knn(
        self,
        k_neighbors: int,
        eps0: Optional[float] = None,
        fused: Optional[bool] = None,
    ) -> DistributedKnnResult:
        """Exact k nearest neighbours of every dataset point, global ids.

        Adaptive eps expansion over the distributed pairs join (the same
        Hybrid-KNN-join recipe as ``QueryService.knn``): run the candidate
        pass at a starting radius (``eps0``, default the build radius),
        double until every point holds >= min(k, n) candidates (capped at
        the bounding-box diagonal, where everything is a candidate), then
        take the exact per-point top-k by (distance, id) from the final
        pair list.  ``fused`` routes the candidate passes exactly as in
        ``self_join_pairs`` -- the fused ring makes each pass one device
        dispatch.
        """
        k = int(k_neighbors)
        if k < 0:
            raise ValueError(f"k_neighbors must be >= 0, got {k}")
        n = self.num_points
        indices = np.full((n, k), -1, np.int64)
        distances = np.full((n, k), np.inf, np.float64)
        if n == 0 or k == 0:
            return DistributedKnnResult(
                indices=indices, distances=distances,
                counts=np.zeros(n, np.int64), eps_used=0.0, eps_rounds=0,
                stats=SelfJoinStats(
                    num_points=n, num_dims=self.num_dims,
                    num_workers=self.num_workers,
                ),
            )
        k_eff = min(k, n)
        lo = self._pts.min(axis=0).astype(np.float64)
        hi = self._pts.max(axis=0).astype(np.float64)
        eps_cap = float(np.sqrt(((hi - lo) ** 2).sum())) * (1.0 + 2**-10) + 1e-6
        eps = self.config.eps if eps0 is None else float(eps0)
        if eps <= 0.0:  # an eps==0 start would never grow by doubling
            eps = eps_cap / 1024.0
        eps = min(eps, eps_cap)
        rounds = 0
        while True:
            obs.event("ring.knn.round", "ring", round=rounds, eps=eps, k=k)
            res = self.self_join_pairs(eps=eps, fused=fused)
            rounds += 1
            if (res.counts >= k_eff).all() or eps >= eps_cap:
                break
            eps = min(2.0 * eps, eps_cap)
        indices, distances = self._topk_from_pairs(res.pairs, k)
        return DistributedKnnResult(
            indices=indices, distances=distances, counts=res.counts,
            eps_used=eps, eps_rounds=rounds, stats=res.stats,
        )

    def _topk_from_pairs(
        self, pairs: np.ndarray, k: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact per-point top-k over the candidate pairs, float64 distances."""
        n = self.num_points
        indices = np.full((n, k), -1, np.int64)
        distances = np.full((n, k), np.inf, np.float64)
        if pairs.shape[0] == 0:
            return indices, distances
        qi = pairs[:, 0].astype(np.int64)
        di = pairs[:, 1].astype(np.int64)
        diffs = self._pts[qi].astype(np.float64) - self._pts[di].astype(
            np.float64
        )
        dist = np.sqrt((diffs * diffs).sum(axis=1))
        order = np.lexsort((di, dist, qi))
        qi, di, dist = qi[order], di[order], dist[order]
        seg = np.cumsum(np.bincount(qi, minlength=n))
        starts = np.concatenate([[0], seg[:-1]])
        rank = np.arange(qi.shape[0]) - starts[qi]
        keep = rank < k
        indices[qi[keep], rank[keep]] = di[keep]
        distances[qi[keep], rank[keep]] = dist[keep]
        return indices, distances
