"""Grid-indexed distributed self-join (paper Sec. 6 + DESIGN.md #7).

``DistributedSelfJoinEngine`` composes the three pieces the repo grew
separately, into the design the paper actually describes:

  * **entity partitioning** (``core/partition.py``, Sec. 6.2): the query set
    is over-decomposed into N_b batches and assigned to the |p| workers --
    round-robin by default, or cost-estimate-driven LPT (``assign_dynamic``)
    when per-batch cost estimates are requested (paper Figs. 10-11);
  * **ring rotation** (``core/distributed.py``, Sec. 6.3): the dataset is
    entity-partitioned into |p| shards E_0..E_{p-1}; in round r worker k
    holds shard (k - r) mod |p|, so after |p| BSP supersteps every query
    batch has met the whole dataset while only (|p|-1)|D| points crossed
    the wire;
  * **the grid index** (``core/grid.py`` / ``core/engine.py``, Secs. 3-4):
    each worker's local join per round runs through ``build_grid`` /
    ``build_query_tile_plan`` + the chunked tile-evaluation programs of
    ``SelfJoinEngine.count_query`` -- REORDER, SORTIDU window pruning and
    SHORTC included.

The last point is the repair this class exists for: the earlier ring driver
evaluated every (Q_k, E_j) block pair with a dense brute-force matmul,
discarding the index whose filtering is the paper's central contribution
(the distance-similarity predecessor, Gowanlock & Karsin arXiv:1803.04120,
is explicit that every worker runs the full indexed join on its batches).
``SelfJoinResult.stats`` therefore reports both ``num_candidates`` (what the
index evaluated) and ``num_candidates_dense`` (the |Q| x |E| volume the dense
ring pays): their ratio is the distributed filtering power.

Execution model: index construction is host-side (as in the paper) and the
per-round tile evaluation is device code.  Two drivers share that contract:

  * the **host-driven** BSP loop (default): the schedule re-enters Python
    between rounds, so it runs identically on 1 or 8 simulated devices and
    serves as the differential oracle for
  * the **device-fused** ring (``fused=True``): the per-(worker, round)
    query tile tables and pair lists are packed host-side into uniform
    (fleet-max-padded, sentinel-masked) arrays, the dataset shards' tile
    tables become the ``ppermute`` ring payload of
    ``core.distributed.ring_scan``, and the |p| rounds run as a
    ``fori_loop`` inside ONE compiled ``shard_map`` program -- each round
    evaluated through the same chunked count step as
    ``SelfJoinEngine.count_query`` (``engine.count_chunk_step``).  One
    trace, one dispatch per join; eps stays a traced scalar so an eps sweep
    re-executes the same program.

Unequal shards from a non-divisible |D| need no sentinel padding on the
host-driven path (shard tile tables are per-shard anyway); the fused path
pads every table to the fleet-wide maximum -- padded tiles carry length 0,
padded pair-list entries sit past the per-chunk ``real`` prefix, and padded
query slots scatter to an out-of-range sentinel dropped by ``mode="drop"``.
"""
from __future__ import annotations

from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.core.distributed import ring_comm_elements, ring_scan
from repro.core.engine import SelfJoinEngine, count_chunk_step
from repro.core.grid import adjacent_cell_pairs, build_grid, pad_axis0
from repro.core.partition import EntityPartition, assign_dynamic, make_partition
from repro.core.reorder import variance_reorder
from repro.core.types import (
    EngineConfig,
    SelfJoinConfig,
    SelfJoinResult,
    SelfJoinStats,
)
from repro.kernels import ops

AxisNames = Union[str, Tuple[str, ...]]


def _mesh_workers(mesh, axes: AxisNames) -> int:
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    size = 1
    for a in axes_t:
        size *= mesh.shape[a]
    return int(size)


class DistributedSelfJoinEngine:
    """Entity-partitioned, grid-indexed ring self-join over |p| workers.

    ``num_workers`` may be given directly or derived from a ``jax`` mesh
    (``mesh=`` plus the ``axes`` the ring spans -- a 1-axis ``("data",)``
    mesh and the joint ``("pod", "data")`` mesh both work; the ring simply
    spans the product of the named axes, as in ``ring_self_join_counts``).

    ``assignment="round_robin"`` reproduces the paper's default batch
    assignment; ``assignment="dynamic"`` runs the sampling-style cost
    estimate (adjacent-cell candidate volume per batch) through the greedy
    LPT scheduler for straggler mitigation (paper Sec. 6.2).

    ``fused=True`` (requires a mesh whose ring size equals ``num_workers``)
    compiles the whole BSP schedule into one ``shard_map`` program --
    ``count()`` then costs exactly one device dispatch and an eps sweep
    re-executes the same executable (see module docstring / DESIGN.md #7a).
    The default host-driven loop is its differential oracle.
    """

    def __init__(
        self,
        d: np.ndarray,
        config: SelfJoinConfig,
        *,
        num_workers: Optional[int] = None,
        mesh=None,
        axes: AxisNames = "data",
        num_batches: Optional[int] = None,
        assignment: str = "round_robin",
        engine_config: Optional[EngineConfig] = None,
        fused: bool = False,
    ):
        if num_workers is None:
            if mesh is None:
                raise ValueError("pass num_workers or a mesh")
            num_workers = _mesh_workers(mesh, axes)
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if assignment not in ("round_robin", "dynamic"):
            raise ValueError(f"unknown assignment {assignment!r}")
        if fused:
            if mesh is None:
                raise ValueError("fused=True needs a mesh (one ring position per device)")
            if num_workers != _mesh_workers(mesh, axes):
                raise ValueError(
                    "fused=True requires num_workers == mesh ring size "
                    f"({num_workers} != {_mesh_workers(mesh, axes)})"
                )

        self.config = config
        self.engine_config = engine_config
        self.mesh = mesh
        self.axes = axes
        self._pts = np.ascontiguousarray(np.asarray(d, dtype=np.float32))
        self.num_points, self.num_dims = self._pts.shape
        self.num_workers = int(num_workers)

        # dataset shards E_j: contiguous entity partition, unequal tails ok
        self.shard_bounds = np.linspace(
            0, self.num_points, self.num_workers + 1
        ).round().astype(np.int64)
        self.shards: List[SelfJoinEngine] = [
            SelfJoinEngine(
                self._pts[self.shard_bounds[j]:self.shard_bounds[j + 1]],
                config,
                engine_config,
            )
            for j in range(self.num_workers)
        ]

        # query batches Q_l, over-decomposed (N_b defaults to 4|p|)
        n_b = num_batches if num_batches is not None else 4 * self.num_workers
        self.partition: EntityPartition = make_partition(
            self.num_points, self.num_workers, n_b
        )
        self._batch_costs: Optional[np.ndarray] = None
        if assignment == "dynamic":
            self.partition.assignment = assign_dynamic(
                self.estimate_batch_costs(), self.num_workers
            )
        self.assignment = assignment

        # fused-ring state (built lazily on the first fused count)
        self.fused = bool(fused)
        self._fused_pack = None       # packed tables + compiled program
        self.fused_traces = 0         # times the fused program was traced
        self.fused_executions = 0     # times it was executed

    # -- partitioning -----------------------------------------------------

    def worker_query_index(self, worker: int) -> np.ndarray:
        """Original-order indices of all query points owned by ``worker``."""
        ranges = [
            np.arange(*self.partition.query_range(b), dtype=np.int64)
            for b in self.partition.batches_of(worker)
        ]
        if not ranges:
            return np.zeros(0, np.int64)
        return np.concatenate(ranges)

    def estimate_batch_costs(self) -> np.ndarray:
        """Per-batch candidate-volume estimates from one global grid probe.

        The cost of joining a batch is dominated by its candidate count; the
        grid gives it cheaply: for every point, the total population of its
        3^k adjacent non-empty cells.  One ``build_grid`` over the full
        (reordered) dataset plus one vectorized adjacency probe -- the same
        sampling-pass flavour the paper uses to drive its scheduler.
        """
        if self._batch_costs is not None:
            return self._batch_costs
        costs = np.zeros(self.partition.num_batches, dtype=np.float64)
        if self.num_points == 0:
            self._batch_costs = costs
            return costs
        work = self._pts
        if self.config.reorder:
            work, _ = variance_reorder(self._pts, self.config.sample_frac)
        grid = build_grid(work, self.config.eps, self.config.k)
        ca, cb = adjacent_cell_pairs(grid)
        cell_cand = np.zeros(grid.num_cells, dtype=np.float64)
        np.add.at(cell_cand, ca, grid.cell_count[cb].astype(np.float64))
        cell_of_point = np.repeat(
            np.arange(grid.num_cells, dtype=np.int64), grid.cell_count
        )
        per_point = np.empty(self.num_points, dtype=np.float64)
        per_point[grid.point_order] = cell_cand[cell_of_point]
        for b in range(self.partition.num_batches):
            lo, hi = self.partition.query_range(b)
            costs[b] = per_point[lo:hi].sum()
        self._batch_costs = costs
        return costs

    def worker_loads(self) -> np.ndarray:
        """Estimated candidate load per worker under the current assignment."""
        costs = self.estimate_batch_costs()
        loads = np.zeros(self.num_workers, dtype=np.float64)
        for b in range(self.partition.num_batches):
            loads[self.partition.assignment[b]] += costs[b]
        return loads

    # -- ring schedule ----------------------------------------------------

    def ring_schedule(self) -> List[List[Tuple[int, int]]]:
        """Round r -> [(worker k, shard it holds)]: shard (k - r) mod |p|."""
        p = self.num_workers
        return [[(k, (k - r) % p) for k in range(p)] for r in range(p)]

    def comm_elements(self) -> int:
        """Ring transport volume in points: (|p| - 1) |D| (paper Sec. 6.3)."""
        return ring_comm_elements(self.num_points, self.num_workers)

    # -- fused device ring (DESIGN.md #7 addendum) -------------------------

    def _pack_fused(self, eps: float):
        """Pack the fused ring's device tables and compile its program.

        Everything host-side happens here, once per index radius: the |p|^2
        bipartite query plans (worker k's batches binned into shard j's
        grid, j = (k - r) mod |p| for round r), padded to fleet-wide maxima
        so one trace fits every ring position, plus the padded shard tile
        tables that form the rotating payload.  eps is NOT baked in -- the
        program takes it as a traced scalar, so a sweep at or below the
        packed radius reuses both the pack and the compiled executable.
        """
        p = self.num_workers
        cfg = self.config
        eng = self.engine_config or EngineConfig()
        t = cfg.tile_size
        n_pad = self.shards[0].snapshot.n_pad

        q_index = [self.worker_query_index(k) for k in range(p)]
        q_pts = [self._pts[idx] for idx in q_index]
        nq = [int(idx.size) for idx in q_index]
        max_nq = max(max(nq), 1)

        # |p|^2 host-side bipartite plans: worker k meets shard (k - r) % p
        # in round r (None where either side is empty -> fully masked round)
        qplans = [
            [self.shards[(k - r) % p].build_query_plan(q_pts[k], eps)
             if nq[k] else None
             for r in range(p)]
            for k in range(p)
        ]
        flat = [qp for row in qplans for qp in row if qp is not None]
        max_qt = max(max((qp.num_q_tiles for qp in flat), default=0), 1)
        max_dt = max(max((e.snapshot.plan.num_tiles if e.snapshot.plan else 0
                  for e in self.shards), default=0), 1)
        max_pr = max((qp.num_pairs for qp in flat), default=0)
        chunk = max(1, min(eng.count_chunk, max(max_pr, 1)))
        n_chunks = max(-(-max_pr // chunk), 1)

        qt = np.zeros((p, p, max_qt, t, n_pad), np.float32)
        qstart = np.zeros((p, p, max_qt), np.int32)
        qlen = np.zeros((p, p, max_qt), np.int32)
        qord = np.full((p, p, max_nq), max_nq, np.int32)   # sentinel: dropped
        pq = np.zeros((p, p, n_chunks, chunk), np.int32)
        pd = np.zeros((p, p, n_chunks, chunk), np.int32)
        real = np.zeros((p, p, n_chunks), np.int32)
        dt = np.zeros((p, max_dt, t, n_pad), np.float32)
        dlen = np.zeros((p, max_dt), np.int32)

        for j, e in enumerate(self.shards):
            dt[j], dlen[j] = e.snapshot.packed_tile_table(max_dt)

        stats_pairs_total = stats_pairs_eval = stats_candidates = 0
        for k in range(p):
            for r in range(p):
                qp = qplans[k][r]
                if qp is None:
                    continue
                stats_pairs_total += qp.num_tile_pairs_total
                stats_pairs_eval += qp.num_pairs
                stats_candidates += qp.num_candidates
                if qp.num_q_tiles:
                    tiles_kr, len_kr = ops.make_tiles(
                        qp.q_sorted, qp.q_tile_start, qp.q_tile_len, t, cfg.dim_block
                    )
                    qt[k, r, : tiles_kr.shape[0]] = tiles_kr
                    qlen[k, r] = pad_axis0(len_kr, max_qt)
                    qstart[k, r] = pad_axis0(qp.q_tile_start, max_qt)
                qord[k, r, : nq[k]] = qp.q_order.astype(np.int32)
                if qp.num_pairs:
                    pq[k, r].reshape(-1)[: qp.num_pairs] = qp.pair_q
                    # B side indexes the concatenated [query | shard] table
                    pd[k, r].reshape(-1)[: qp.num_pairs] = qp.pair_d + max_qt
                    real[k, r] = np.clip(
                        qp.num_pairs - np.arange(n_chunks) * chunk, 0, chunk
                    ).astype(np.int32)

        axes_t = (self.axes,) if isinstance(self.axes, str) else tuple(self.axes)
        ax = axes_t if len(axes_t) > 1 else axes_t[0]
        backend = "pallas" if cfg.use_pallas else "jnp"
        interpret = eng.interpret
        engine_self = self

        def local(qt, qstart, qlen, qord, pq, pd, real, dt, dlen, eps_in):
            engine_self.fused_traces += 1  # traced once; executions replay it
            qt, qstart, qlen, qord = qt[0], qstart[0], qlen[0], qord[0]
            pq, pd, real = pq[0], pd[0], real[0]
            dt, dlen = dt[0], dlen[0]

            def round_body(r, counts_local, payload):
                d_tiles, d_len = payload
                tiles = jnp.concatenate([qt[r], d_tiles], axis=0)
                tlen = jnp.concatenate([qlen[r], d_len])
                # B-side starts are never read (only pair_a rows scatter)
                tstart = jnp.concatenate([qstart[r], jnp.zeros_like(d_len)])

                def chunk_body(c, counts_sorted):
                    counts_sorted, _ = count_chunk_step(
                        counts_sorted, jnp.zeros((), jnp.int32),
                        tiles, tlen, tstart,
                        pq[r, c], pd[r, c], real[r, c], eps_in,
                        dim_block=cfg.dim_block, shortc=cfg.shortc,
                        backend=backend, interpret=interpret,
                    )
                    return counts_sorted

                counts_sorted = jax.lax.fori_loop(
                    0, n_chunks, chunk_body, jnp.zeros(max_nq, jnp.int32)
                )
                # per-round q_order: q-sorted position -> worker-local slot
                return counts_local.at[qord[r]].add(counts_sorted, mode="drop")

            counts0 = compat.pvary(jnp.zeros(max_nq, jnp.int32), axes_t)
            counts = ring_scan(axes_t, round_body, counts0, (dt, dlen))
            return counts[None]

        def pspec(arr):
            return P(ax, *([None] * (arr.ndim - 1)))

        # tables go device-resident (with their ring sharding) at pack time:
        # repeat joins and eps sweeps then transfer only the eps scalar
        args = tuple(
            jax.device_put(a, NamedSharding(self.mesh, pspec(a)))
            for a in (qt, qstart, qlen, qord, pq, pd, real, dt, dlen)
        )
        fn = jax.jit(
            compat.shard_map(
                local,
                mesh=self.mesh,
                in_specs=tuple(pspec(a) for a in args) + (P(),),
                out_specs=P(ax, None),
                # pallas_call has no replication rule; the program's outputs
                # are device-varying by construction, so the check adds
                # nothing here
                check_rep=not cfg.use_pallas,
            )
        )
        self._fused_pack = dict(
            eps=float(eps), fn=fn, args=args,
            q_index=q_index, nq=nq, n_chunks=n_chunks,
            stats=(stats_pairs_total, stats_pairs_eval, stats_candidates),
        )
        return self._fused_pack

    def _count_fused(self, eps: float) -> SelfJoinResult:
        """One-dispatch fused ring count (counts == host-driven ``count()``)."""
        pack = self._fused_pack
        if pack is None or eps > pack["eps"]:
            pack = self._pack_fused(max(eps, self.config.eps))
        out = np.asarray(
            jax.device_get(pack["fn"](*pack["args"], jnp.float32(eps)))
        )
        self.fused_executions += 1
        counts = np.zeros(self.num_points, dtype=np.int64)
        for k in range(self.num_workers):
            counts[pack["q_index"][k]] = out[k, : pack["nq"][k]]
        pairs_total, pairs_eval, candidates = pack["stats"]
        shard_sizes = np.diff(self.shard_bounds)
        stats = SelfJoinStats(
            num_points=self.num_points,
            num_dims=self.num_dims,
            k=min(self.config.k, self.num_dims),
            num_workers=self.num_workers,
            num_rounds=self.num_workers,
            comm_elements=self.comm_elements(),
            num_tile_pairs_total=pairs_total,
            num_tile_pairs_evaluated=pairs_eval,
            num_candidates=candidates,
            num_chunks=self.num_workers * pack["n_chunks"],
            num_device_dispatches=1,
            num_candidates_dense=int(
                sum(
                    pack["nq"][k] * shard_sizes[j]
                    for r, sched in enumerate(self.ring_schedule())
                    for k, j in sched
                )
            ),
            num_results=int(counts.sum()),
        )
        stats.num_tiles = sum(
            e.snapshot.plan.num_tiles for e in self.shards if e.snapshot.plan
        )
        stats.num_nonempty_cells = sum(
            e.snapshot.grid.num_cells for e in self.shards if e.snapshot.grid
        )
        return SelfJoinResult(counts=counts, stats=stats)

    # -- queries ----------------------------------------------------------

    def count(self, eps: Optional[float] = None) -> SelfJoinResult:
        """Per-point neighbour counts (self included), original order.

        Executes the |p|-round BSP schedule: in round r every worker joins
        its query batches against the shard it currently holds, through that
        shard's grid index (``SelfJoinEngine.count_query``).  Counts
        accumulate across rounds; after |p| rounds each query point has met
        every shard exactly once, so the result equals the single-device
        ``SelfJoinEngine.count()`` and the brute-force oracle.

        With ``fused=True`` the same schedule runs as one compiled
        ``shard_map`` program (``_count_fused``); this host-driven loop is
        its differential oracle.
        """
        eps = self.config.eps if eps is None else float(eps)
        if self.fused and self.num_points:
            return self._count_fused(eps)
        counts = np.zeros(self.num_points, dtype=np.int64)
        stats = SelfJoinStats(
            num_points=self.num_points,
            num_dims=self.num_dims,
            k=min(self.config.k, self.num_dims),
            num_workers=self.num_workers,
            comm_elements=self.comm_elements(),
        )
        q_index = [self.worker_query_index(k) for k in range(self.num_workers)]
        q_points = [self._pts[idx] for idx in q_index]
        shard_sizes = np.diff(self.shard_bounds)
        for round_sched in self.ring_schedule():
            for k, j in round_sched:
                if q_index[k].size == 0:
                    continue
                res = self.shards[j].count_query(q_points[k], eps)
                counts[q_index[k]] += res.counts
                s = res.stats
                stats.num_tile_pairs_total += s.num_tile_pairs_total
                stats.num_tile_pairs_evaluated += s.num_tile_pairs_evaluated
                stats.num_candidates += s.num_candidates
                stats.num_chunks += s.num_chunks
                stats.num_device_dispatches += s.num_chunks
                stats.dim_blocks_skipped += s.dim_blocks_skipped
                stats.dim_blocks_total += s.dim_blocks_total
                stats.num_candidates_dense += int(q_index[k].size * shard_sizes[j])
            stats.num_rounds += 1
        stats.num_tiles = sum(
            e.snapshot.plan.num_tiles for e in self.shards if e.snapshot.plan
        )
        stats.num_nonempty_cells = sum(
            e.snapshot.grid.num_cells for e in self.shards if e.snapshot.grid
        )
        stats.num_results = int(counts.sum())
        return SelfJoinResult(counts=counts, stats=stats)
