"""Grid-indexed distributed self-join (paper Sec. 6 + DESIGN.md #7).

``DistributedSelfJoinEngine`` composes the three pieces the repo grew
separately, into the design the paper actually describes:

  * **entity partitioning** (``core/partition.py``, Sec. 6.2): the query set
    is over-decomposed into N_b batches and assigned to the |p| workers --
    round-robin by default, or cost-estimate-driven LPT (``assign_dynamic``)
    when per-batch cost estimates are requested (paper Figs. 10-11);
  * **ring rotation** (``core/distributed.py``, Sec. 6.3): the dataset is
    entity-partitioned into |p| shards E_0..E_{p-1}; in round r worker k
    holds shard (k - r) mod |p|, so after |p| BSP supersteps every query
    batch has met the whole dataset while only (|p|-1)|D| points crossed
    the wire;
  * **the grid index** (``core/grid.py`` / ``core/engine.py``, Secs. 3-4):
    each worker's local join per round runs through ``build_grid`` /
    ``build_query_tile_plan`` + the chunked tile-evaluation programs of
    ``SelfJoinEngine.count_query`` -- REORDER, SORTIDU window pruning and
    SHORTC included.

The last point is the repair this class exists for: the earlier ring driver
evaluated every (Q_k, E_j) block pair with a dense brute-force matmul,
discarding the index whose filtering is the paper's central contribution
(the distance-similarity predecessor, Gowanlock & Karsin arXiv:1803.04120,
is explicit that every worker runs the full indexed join on its batches).
``SelfJoinResult.stats`` therefore reports both ``num_candidates`` (what the
index evaluated) and ``num_candidates_dense`` (the |Q| x |E| volume the dense
ring pays): their ratio is the distributed filtering power.

Execution model: index construction is host-side (as in the paper) and the
per-round tile evaluation is device code; this class drives the BSP schedule
from the host, so it runs identically on 1 or 8 simulated devices.  The
wire-protocol realization of the rotation (``shard_map`` + ``ppermute``)
lives in ``core/distributed.py`` and ``launch/selfjoin_dryrun.py``; on real
hardware the tile tables built here are exactly the payloads those ppermute
rounds carry.  Unequal shards from a non-divisible |D| need no sentinel
padding here -- shard tile tables are per-shard anyway.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.distributed import ring_comm_elements
from repro.core.engine import SelfJoinEngine
from repro.core.grid import adjacent_cell_pairs, build_grid
from repro.core.partition import EntityPartition, assign_dynamic, make_partition
from repro.core.reorder import variance_reorder
from repro.core.types import (
    EngineConfig,
    SelfJoinConfig,
    SelfJoinResult,
    SelfJoinStats,
)

AxisNames = Union[str, Tuple[str, ...]]


def _mesh_workers(mesh, axes: AxisNames) -> int:
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    size = 1
    for a in axes_t:
        size *= mesh.shape[a]
    return int(size)


class DistributedSelfJoinEngine:
    """Entity-partitioned, grid-indexed ring self-join over |p| workers.

    ``num_workers`` may be given directly or derived from a ``jax`` mesh
    (``mesh=`` plus the ``axes`` the ring spans -- a 1-axis ``("data",)``
    mesh and the joint ``("pod", "data")`` mesh both work; the ring simply
    spans the product of the named axes, as in ``ring_self_join_counts``).

    ``assignment="round_robin"`` reproduces the paper's default batch
    assignment; ``assignment="dynamic"`` runs the sampling-style cost
    estimate (adjacent-cell candidate volume per batch) through the greedy
    LPT scheduler for straggler mitigation (paper Sec. 6.2).
    """

    def __init__(
        self,
        d: np.ndarray,
        config: SelfJoinConfig,
        *,
        num_workers: Optional[int] = None,
        mesh=None,
        axes: AxisNames = "data",
        num_batches: Optional[int] = None,
        assignment: str = "round_robin",
        engine_config: Optional[EngineConfig] = None,
    ):
        if num_workers is None:
            if mesh is None:
                raise ValueError("pass num_workers or a mesh")
            num_workers = _mesh_workers(mesh, axes)
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if assignment not in ("round_robin", "dynamic"):
            raise ValueError(f"unknown assignment {assignment!r}")

        self.config = config
        self.engine_config = engine_config
        self.mesh = mesh
        self.axes = axes
        self._pts = np.ascontiguousarray(np.asarray(d, dtype=np.float32))
        self.num_points, self.num_dims = self._pts.shape
        self.num_workers = int(num_workers)

        # dataset shards E_j: contiguous entity partition, unequal tails ok
        self.shard_bounds = np.linspace(
            0, self.num_points, self.num_workers + 1
        ).round().astype(np.int64)
        self.shards: List[SelfJoinEngine] = [
            SelfJoinEngine(
                self._pts[self.shard_bounds[j]:self.shard_bounds[j + 1]],
                config,
                engine_config,
            )
            for j in range(self.num_workers)
        ]

        # query batches Q_l, over-decomposed (N_b defaults to 4|p|)
        n_b = num_batches if num_batches is not None else 4 * self.num_workers
        self.partition: EntityPartition = make_partition(
            self.num_points, self.num_workers, n_b
        )
        self._batch_costs: Optional[np.ndarray] = None
        if assignment == "dynamic":
            self.partition.assignment = assign_dynamic(
                self.estimate_batch_costs(), self.num_workers
            )
        self.assignment = assignment

    # -- partitioning -----------------------------------------------------

    def worker_query_index(self, worker: int) -> np.ndarray:
        """Original-order indices of all query points owned by ``worker``."""
        ranges = [
            np.arange(*self.partition.query_range(b), dtype=np.int64)
            for b in self.partition.batches_of(worker)
        ]
        if not ranges:
            return np.zeros(0, np.int64)
        return np.concatenate(ranges)

    def estimate_batch_costs(self) -> np.ndarray:
        """Per-batch candidate-volume estimates from one global grid probe.

        The cost of joining a batch is dominated by its candidate count; the
        grid gives it cheaply: for every point, the total population of its
        3^k adjacent non-empty cells.  One ``build_grid`` over the full
        (reordered) dataset plus one vectorized adjacency probe -- the same
        sampling-pass flavour the paper uses to drive its scheduler.
        """
        if self._batch_costs is not None:
            return self._batch_costs
        costs = np.zeros(self.partition.num_batches, dtype=np.float64)
        if self.num_points == 0:
            self._batch_costs = costs
            return costs
        work = self._pts
        if self.config.reorder:
            work, _ = variance_reorder(self._pts, self.config.sample_frac)
        grid = build_grid(work, self.config.eps, self.config.k)
        ca, cb = adjacent_cell_pairs(grid)
        cell_cand = np.zeros(grid.num_cells, dtype=np.float64)
        np.add.at(cell_cand, ca, grid.cell_count[cb].astype(np.float64))
        cell_of_point = np.repeat(
            np.arange(grid.num_cells, dtype=np.int64), grid.cell_count
        )
        per_point = np.empty(self.num_points, dtype=np.float64)
        per_point[grid.point_order] = cell_cand[cell_of_point]
        for b in range(self.partition.num_batches):
            lo, hi = self.partition.query_range(b)
            costs[b] = per_point[lo:hi].sum()
        self._batch_costs = costs
        return costs

    def worker_loads(self) -> np.ndarray:
        """Estimated candidate load per worker under the current assignment."""
        costs = self.estimate_batch_costs()
        loads = np.zeros(self.num_workers, dtype=np.float64)
        for b in range(self.partition.num_batches):
            loads[self.partition.assignment[b]] += costs[b]
        return loads

    # -- ring schedule ----------------------------------------------------

    def ring_schedule(self) -> List[List[Tuple[int, int]]]:
        """Round r -> [(worker k, shard it holds)]: shard (k - r) mod |p|."""
        p = self.num_workers
        return [[(k, (k - r) % p) for k in range(p)] for r in range(p)]

    def comm_elements(self) -> int:
        """Ring transport volume in points: (|p| - 1) |D| (paper Sec. 6.3)."""
        return ring_comm_elements(self.num_points, self.num_workers)

    # -- queries ----------------------------------------------------------

    def count(self, eps: Optional[float] = None) -> SelfJoinResult:
        """Per-point neighbour counts (self included), original order.

        Executes the |p|-round BSP schedule: in round r every worker joins
        its query batches against the shard it currently holds, through that
        shard's grid index (``SelfJoinEngine.count_query``).  Counts
        accumulate across rounds; after |p| rounds each query point has met
        every shard exactly once, so the result equals the single-device
        ``SelfJoinEngine.count()`` and the brute-force oracle.
        """
        eps = self.config.eps if eps is None else float(eps)
        counts = np.zeros(self.num_points, dtype=np.int64)
        stats = SelfJoinStats(
            num_points=self.num_points,
            num_dims=self.num_dims,
            k=min(self.config.k, self.num_dims),
            num_workers=self.num_workers,
            comm_elements=self.comm_elements(),
        )
        q_index = [self.worker_query_index(k) for k in range(self.num_workers)]
        q_points = [self._pts[idx] for idx in q_index]
        shard_sizes = np.diff(self.shard_bounds)
        for round_sched in self.ring_schedule():
            for k, j in round_sched:
                if q_index[k].size == 0:
                    continue
                res = self.shards[j].count_query(q_points[k], eps)
                counts[q_index[k]] += res.counts
                s = res.stats
                stats.num_tile_pairs_total += s.num_tile_pairs_total
                stats.num_tile_pairs_evaluated += s.num_tile_pairs_evaluated
                stats.num_candidates += s.num_candidates
                stats.num_chunks += s.num_chunks
                stats.dim_blocks_skipped += s.dim_blocks_skipped
                stats.dim_blocks_total += s.dim_blocks_total
                stats.num_candidates_dense += int(q_index[k].size * shard_sizes[j])
            stats.num_rounds += 1
        stats.num_tiles = sum(e.plan.num_tiles for e in self.shards if e.plan)
        stats.num_nonempty_cells = sum(
            e.grid.num_cells for e in self.shards if e.grid
        )
        stats.num_results = int(counts.sum())
        return SelfJoinResult(counts=counts, stats=stats)
