"""REORDER -- dimensionality reordering by variance (paper Section 4.2).

The variance of each dimension is estimated on a sample of ``sample_frac`` of
|D| (the paper uses 1%), and the coordinate columns of every point are
permuted so variances are in descending order.  Reordering swaps coordinate
values only, so the pairwise Euclidean distances -- and hence the join result
-- are unchanged; the indexed prefix of dimensions (Section 4.1) gains
filtering power.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def estimate_dim_variance(
    d: np.ndarray, sample_frac: float = 0.01, seed: int = 0
) -> np.ndarray:
    """Per-dimension variance estimated from a random sample of the points."""
    pts = np.asarray(d)
    n_pts = pts.shape[0]
    if n_pts <= 2:
        return pts.var(axis=0) if n_pts else np.zeros(pts.shape[1])
    n_sample = max(2, min(n_pts, int(round(n_pts * sample_frac))))
    rng = np.random.default_rng(seed)
    idx = rng.choice(n_pts, size=n_sample, replace=False)
    return pts[idx].var(axis=0)


def variance_reorder(
    d: np.ndarray, sample_frac: float = 0.01, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Return (reordered points, dim permutation), descending variance.

    ``reordered[:, j] == d[:, perm[j]]``; applying the join to the reordered
    data yields identical pairs/counts (distances are permutation-invariant).
    """
    pts = np.asarray(d)
    var = estimate_dim_variance(pts, sample_frac, seed)
    # stable sort so equal-variance dims keep their input order (determinism)
    perm = np.argsort(-var, kind="stable")
    return np.ascontiguousarray(pts[:, perm]), perm
