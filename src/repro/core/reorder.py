"""REORDER -- dimensionality reordering by variance (paper Section 4.2).

The variance of each dimension is estimated on a sample of ``sample_frac`` of
|D| (the paper uses 1%), and the coordinate columns of every point are
permuted so variances are in descending order.  Reordering swaps coordinate
values only, so the pairwise Euclidean distances -- and hence the join result
-- are unchanged; the indexed prefix of dimensions (Section 4.1) gains
filtering power.

``apply_reorder`` / ``inverse_perm`` are the supported way to carry the same
permutation to *external* points: a serving tier that indexes D once must
permute every incoming query batch identically (``repro.join``), and
``inverse_perm`` undoes it for round-tripping back to original coordinates.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def apply_reorder(points: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Permute coordinate columns: ``out[:, j] == points[:, perm[j]]``.

    The companion of ``variance_reorder`` for points that were not part of
    the reordered dataset (e.g. query batches against a persisted index).
    Distances between any two points are unchanged.
    """
    pts = np.asarray(points)
    return np.ascontiguousarray(pts[:, np.asarray(perm)])


def inverse_perm(perm: np.ndarray) -> np.ndarray:
    """The permutation undoing ``perm``: ``apply_reorder(apply_reorder(d, p), inverse_perm(p)) == d``."""
    p = np.asarray(perm)
    inv = np.empty_like(p)
    inv[p] = np.arange(p.shape[0], dtype=p.dtype)
    return inv


def estimate_dim_variance(
    d: np.ndarray,
    sample_frac: float = 0.01,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Per-dimension variance estimated from a random sample of the points.

    Pass ``rng`` to draw from a caller-owned generator (so successive calls
    use independent samples); otherwise a fresh ``default_rng(seed)`` keeps
    the historical deterministic behaviour.
    """
    pts = np.asarray(d)
    n_pts = pts.shape[0]
    if n_pts <= 2:
        return pts.var(axis=0) if n_pts else np.zeros(pts.shape[1])
    n_sample = max(2, min(n_pts, int(round(n_pts * sample_frac))))
    if rng is None:
        rng = np.random.default_rng(seed)
    idx = rng.choice(n_pts, size=n_sample, replace=False)
    return pts[idx].var(axis=0)


def variance_reorder(
    d: np.ndarray,
    sample_frac: float = 0.01,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Return (reordered points, dim permutation), descending variance.

    ``reordered == apply_reorder(d, perm)``; applying the join to the
    reordered data yields identical pairs/counts (distances are
    permutation-invariant).
    """
    pts = np.asarray(d)
    var = estimate_dim_variance(pts, sample_frac, seed, rng=rng)
    # stable sort so equal-variance dims keep their input order (determinism)
    perm = np.argsort(-var, kind="stable")
    return apply_reorder(pts, perm), perm
