"""GPU-Join (paper Alg. 1), TPU-native: the top-level self-join driver.

Pipeline (paper lines 1-10, adapted per DESIGN.md #1):

  1. REORDER the dimensions by sampled variance          (Sec. 4.2)
  2. build the grid index over the first k dims          (Secs. 3.2.1, 4.1)
  3. build the candidate tile-pair plan, SORTIDU-pruned  (Sec. 4.3)
  4. estimate the result size, preallocate the pairs
     buffer / derive batches                             (Sec. 3.2.2)
  5. evaluate chunks with the tile distance kernel
     (SHORTC dimension-blocked pruning)                  (Sec. 4.4)
  6. scatter per-point counts / compact pairs back to
     the original point order (constructNeighborTable)

``self_join`` is a thin wrapper over the device-resident
``repro.core.engine.SelfJoinEngine``, which keeps steps 4-6 on the
accelerator (DESIGN.md #1.5).  ``config.execution`` selects the execution
tier (DESIGN.md #9): ``"indexed"`` runs the pipeline above; ``"dense"``
skips index filtering and evaluates the full tile cross product with the
clamped matmul-identity kernel (``kernels/dense_tile.py``); ``"auto"``
compares the cost model's two estimates (``repro.core.cost``) and picks the
cheaper tier -- the decision and both estimates are recorded in
``SelfJoinStats``.  The original host-loop implementation is preserved as
``self_join_hostloop`` -- it is the baseline that
``benchmarks/bench_engine.py`` measures the engine against, and a second
oracle for parity tests; it is indexed-tier only.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import batching as batching_mod
from repro.core.engine import SelfJoinEngine
from repro.core.grid import build_grid, build_tile_plan
from repro.core.reorder import variance_reorder
from repro.core.types import SelfJoinConfig, SelfJoinResult, SelfJoinStats
from repro.kernels import ops


def self_join(
    d: np.ndarray,
    config: SelfJoinConfig,
    return_pairs: bool = False,
    max_pairs: Optional[int] = None,
) -> SelfJoinResult:
    """Find all ordered pairs within config.eps; counts per original point."""
    engine = SelfJoinEngine(d, config)
    if return_pairs:
        return engine.pairs(max_pairs=max_pairs)
    return engine.count()


def self_join_hostloop(
    d: np.ndarray,
    config: SelfJoinConfig,
    return_pairs: bool = False,
    max_pairs: Optional[int] = None,
) -> SelfJoinResult:
    """Pre-engine reference path: host-side tiling loop, ``np.add.at``
    count scatter and ``np.nonzero`` pair extraction between device calls.

    Kept for benchmarking (the engine must at least match it) and as an
    independent oracle.
    """
    pts = np.ascontiguousarray(np.asarray(d, dtype=np.float32))
    n_pts, n = pts.shape
    stats = SelfJoinStats(num_points=n_pts, num_dims=n, k=min(config.k, n))
    if n_pts == 0:
        return SelfJoinResult(counts=np.zeros(0, np.int64), stats=stats,
                              pairs=np.zeros((0, 2), np.int32) if return_pairs else None)

    work = pts
    if config.reorder:
        work, _ = variance_reorder(pts, config.sample_frac)

    grid = build_grid(work, config.eps, config.k)
    plan = build_tile_plan(grid, config.tile_size, config.sortidu)
    stats.num_nonempty_cells = grid.num_cells
    stats.num_tiles = plan.num_tiles
    stats.num_tile_pairs_total = plan.num_tile_pairs_total
    stats.num_tile_pairs_evaluated = plan.num_pairs
    stats.num_candidates = plan.num_candidates

    tiles_pts, tile_len = ops.make_tiles(
        grid.pts_sorted, plan.tile_start, plan.tile_len,
        config.tile_size, config.dim_block,
    )
    backend = "pallas" if config.use_pallas else "jnp"
    n_pad = tiles_pts.shape[2]
    nb_blocks = n_pad // config.dim_block

    counts_sorted = np.zeros(n_pts, dtype=np.int64)
    pairs_out: List[np.ndarray] = []
    t = config.tile_size
    lane = np.arange(t, dtype=np.int64)

    if return_pairs:
        # batching (Sec. 3.2.2): estimate |R|, derive n_b, process batch-wise
        est = batching_mod.estimate_result_size(
            tiles_pts, tile_len, plan, eps=config.eps,
            dim_block=config.dim_block, backend=backend,
            sample_frac=config.sample_frac,
        )
        n_b = batching_mod.compute_num_batches(
            est, config.batch_size, config.min_batches
        )
        for lo, hi in batching_mod.batch_ranges(plan.num_pairs, n_b):
            pa, pb = plan.pair_a[lo:hi], plan.pair_b[lo:hi]
            for off, mask in ops.tile_mask(
                tiles_pts, tile_len, pa, pb, eps=config.eps,
                dim_block=config.dim_block, backend=backend,
            ):
                pp, ii, jj = np.nonzero(mask)
                a_sorted = plan.tile_start[pa[off + pp]].astype(np.int64) + ii
                b_sorted = plan.tile_start[pb[off + pp]].astype(np.int64) + jj
                np.add.at(counts_sorted, a_sorted, 1)
                a_orig = grid.point_order[a_sorted]
                b_orig = grid.point_order[b_sorted]
                pairs_out.append(
                    np.stack([a_orig, b_orig], axis=1).astype(np.int32)
                )
                if max_pairs is not None and sum(x.shape[0] for x in pairs_out) > max_pairs:
                    raise RuntimeError(
                        f"result exceeded max_pairs={max_pairs}; raise the cap "
                        f"or lower eps"
                    )
        stats.dim_blocks_total = plan.num_pairs * nb_blocks
    else:
        counts_pair, skipped = ops.tile_counts(
            tiles_pts, tile_len, plan.pair_a, plan.pair_b,
            eps=config.eps, dim_block=config.dim_block,
            shortc=config.shortc, backend=backend,
        )
        pa = plan.pair_a
        idx = plan.tile_start[pa].astype(np.int64)[:, None] + lane[None, :]
        valid = lane[None, :] < plan.tile_len[pa][:, None]
        np.add.at(
            counts_sorted,
            np.where(valid, idx, 0),
            np.where(valid, counts_pair.astype(np.int64), 0),
        )
        stats.dim_blocks_skipped = int(skipped.sum())
        stats.dim_blocks_total = plan.num_pairs * nb_blocks

    counts = np.zeros(n_pts, dtype=np.int64)
    counts[grid.point_order] = counts_sorted
    stats.num_results = int(counts.sum())

    pairs = np.concatenate(pairs_out) if pairs_out else (
        np.zeros((0, 2), np.int32) if return_pairs else None
    )
    return SelfJoinResult(counts=counts, stats=stats, pairs=pairs)
