"""Brute-force O(|D|^2) self-join references.

Two oracles:
  * ``brute_counts`` -- float64 numpy, direct (a-b)^2 formulation.  Ground
    truth for correctness tests.
  * ``brute_counts_f32`` -- float32, matmul formulation, matching the numeric
    path of the TPU kernel (DESIGN.md #6) for bit-comparable testing.

Both operate in blocks so |D| up to ~10^5 stays within memory.
"""
from __future__ import annotations

import numpy as np


def brute_counts(d: np.ndarray, eps: float, block: int = 1024) -> np.ndarray:
    """Number of points within eps of each point (self included), float64."""
    pts = np.asarray(d, dtype=np.float64)
    n = pts.shape[0]
    eps2 = np.float64(eps) ** 2
    counts = np.zeros(n, dtype=np.int64)
    for i0 in range(0, n, block):
        a = pts[i0 : i0 + block]
        for j0 in range(0, n, block):
            b = pts[j0 : j0 + block]
            diff = a[:, None, :] - b[None, :, :]
            d2 = np.einsum("ijk,ijk->ij", diff, diff)
            counts[i0 : i0 + block] += (d2 <= eps2).sum(axis=1)
    return counts


def brute_pairs(d: np.ndarray, eps: float) -> np.ndarray:
    """All ordered (a, b) pairs with dist <= eps, float64. Small inputs only."""
    pts = np.asarray(d, dtype=np.float64)
    diff = pts[:, None, :] - pts[None, :, :]
    d2 = np.einsum("ijk,ijk->ij", diff, diff)
    a, b = np.nonzero(d2 <= np.float64(eps) ** 2)
    return np.stack([a, b], axis=1).astype(np.int32)


def brute_counts_f32(d: np.ndarray, eps: float, block: int = 2048) -> np.ndarray:
    """float32 matmul-form counts: ||a||^2 + ||b||^2 - 2 a.b, matching the kernel."""
    pts = np.asarray(d, dtype=np.float32)
    n = pts.shape[0]
    eps2 = np.float32(eps) ** 2
    norms = np.einsum("ij,ij->i", pts, pts)
    counts = np.zeros(n, dtype=np.int64)
    for i0 in range(0, n, block):
        a = pts[i0 : i0 + block]
        na = norms[i0 : i0 + block]
        d2 = na[:, None] + norms[None, :] - 2.0 * (a @ pts.T)
        counts[i0 : i0 + block] = (d2 <= eps2).sum(axis=1)
    return counts
