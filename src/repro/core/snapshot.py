"""Immutable data snapshot of the self-join engine (DESIGN.md #10).

``GridSnapshot`` is the DATA half of the engine split: everything derived
from the point set -- the points themselves, the REORDER permutation, the
grid, the tile plan, the device-resident packed tile tables and the lazy
dense-tier tables -- lives here as a frozen-by-convention value object.
``SelfJoinEngine`` keeps only configuration and the shape-keyed executable
cache (the module-level jitted chunk programs), so compiled programs are
keyed by (chunk shape, backend, bucket) and never by data identity:
swapping a new snapshot behind a warm engine is one reference assignment
and invalidates nothing.

Shape-bucket contract: the device tile table (``tile_rows``), the combined
bipartite order's data segment (``point_rows``) and the dense tile table
(``dense_rows``) are padded to power-of-two row buckets
(``grid.bucket_rows``); ``rebuilt`` and the mutable index's ``compact``
carry the old snapshot's buckets forward as floors, so a rebuild whose data
still fits the old buckets presents byte-identical array SHAPES to every
executable compiled against the previous snapshot -- the no-retrace
contract ``tests/test_mutation.py`` locks via ``ServiceStats.num_traces``.
Padding tile rows carry ``tile_len == 0`` (the sentinel every chunk
program's validity mask already understands) and are never referenced by a
candidate pair list, so they contribute zero work and zero results.

Nothing here mutates after construction except the two lazy caches (the
per-chunk-size padded pair lists and the dense tables), both of which are
pure functions of the frozen state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grid import (
    GridIndex,
    TilePlan,
    bucket_rows,
    build_grid,
    build_tile_plan,
    pad_axis0,
)
from repro.core.reorder import apply_reorder, variance_reorder
from repro.core.types import SelfJoinConfig
from repro.kernels import ops

# sentinel for GridSnapshot.build's perm argument: "compute it from the
# config", as opposed to an explicit permutation (or explicit None)
_AUTO_PERM = "auto"


def _chunk_list(
    pair_a: np.ndarray, pair_b: np.ndarray, chunk: int, cache: dict
) -> List[Tuple[jax.Array, jax.Array, int]]:
    """Padded device chunks of a candidate pair list, cached per chunk size."""
    got = cache.get(chunk)
    if got is None:
        got = [
            (pa, pb, real)
            for _, pa, pb, real in ops._chunks(pair_a, pair_b, chunk)
        ]
        cache[chunk] = got
    return got


def make_dense_plan(n_points: int, tile_size: int) -> TilePlan:
    """Sequential full-tile plan: the dense tier's work list.

    The indexed tier's tiles follow grid-cell boundaries, so in high
    dimensions (many near-singleton cells) they are mostly padding and the
    tile-pair fan-out explodes.  The dense tier re-tiles ``pts_sorted``
    *sequentially* -- every tile full except the last -- and lists the
    complete tile cross product.  Same ``TilePlan`` type, same chunk
    programs downstream; only the pair list and the per-tile layout differ.
    """
    t = int(tile_size)
    num_tiles = -(-int(n_points) // t) if n_points else 0
    tile_start = np.arange(num_tiles, dtype=np.int64) * t
    tile_len = np.minimum(int(n_points) - tile_start, t)
    idx = np.arange(num_tiles, dtype=np.int64)
    return TilePlan(
        tile_size=t,
        tile_start=tile_start.astype(np.int32),
        tile_len=tile_len.astype(np.int32),
        tile_cell=np.zeros(num_tiles, np.int32),  # no cells in the dense tier
        pair_a=np.repeat(idx, num_tiles).astype(np.int32),
        pair_b=np.tile(idx, num_tiles).astype(np.int32),
        num_tile_pairs_total=num_tiles * num_tiles,
        num_candidates=int(n_points) * int(n_points),
    )


@dataclasses.dataclass
class DenseTables:
    """Device-resident dense-tier twin of the snapshot's indexed tables."""

    plan: TilePlan
    tiles: jax.Array          # (dense_rows, T, n_pad) f32, sequential layout
    tile_len: jax.Array       # (dense_rows,) int32; padding rows are 0
    tile_start: jax.Array     # (dense_rows,) int32 into pts_sorted
    _chunk_cache: Dict[int, list] = dataclasses.field(default_factory=dict)

    def chunks(self, chunk: int) -> List[Tuple[jax.Array, jax.Array, int]]:
        return _chunk_list(self.plan.pair_a, self.plan.pair_b, chunk,
                           self._chunk_cache)


class GridSnapshot:
    """One dataset's complete, frozen index state, resident on device.

    Construct via ``build`` (full pipeline: optional REORDER, grid, tile
    plan, device placement), ``from_arrays`` (the persistence path: arrays
    already built, only device placement runs) or ``rebuilt`` (same points
    at a larger radius, same permutation, buckets floored at this
    snapshot's).  Treat instances as immutable values: a data change means
    a new snapshot and a ``SelfJoinEngine.swap_snapshot``.
    """

    __slots__ = (
        "config", "pts", "perm", "work", "index_eps", "grid", "plan",
        "num_points", "num_dims", "tile_rows", "point_rows", "dense_rows",
        "tiles", "tile_len", "tile_start", "point_order",
        "point_order_padded", "_dense", "_chunk_cache",
    )

    def __init__(
        self,
        config: SelfJoinConfig,
        pts: np.ndarray,
        perm: Optional[np.ndarray],
        work: np.ndarray,
        index_eps: Optional[float],
        grid: Optional[GridIndex],
        plan: Optional[TilePlan],
        *,
        min_tile_rows: int = 1,
        min_point_rows: int = 1,
        min_dense_rows: int = 1,
    ):
        self.config = config
        self.pts = pts
        self.perm = perm
        self.work = work
        self.index_eps = None if index_eps is None else float(index_eps)
        self.grid = grid
        self.plan = plan
        self.num_points, self.num_dims = pts.shape
        n_tiles = plan.num_tiles if plan is not None else 0
        self.tile_rows = bucket_rows(n_tiles, min_tile_rows)
        self.point_rows = bucket_rows(self.num_points, min_point_rows)
        self.dense_rows = bucket_rows(
            -(-self.num_points // config.tile_size), min_dense_rows
        )
        self._dense: Optional[DenseTables] = None
        self._chunk_cache: dict = {}
        if grid is not None:
            self.tile_start = jnp.asarray(
                pad_axis0(plan.tile_start, self.tile_rows), jnp.int32
            )
            self.tile_len = jnp.asarray(
                pad_axis0(plan.tile_len, self.tile_rows), jnp.int32
            )
            # the grid-sort permutation at its REAL length (count scatters
            # and _unsort_counts address exactly N rows) ...
            self.point_order = jnp.asarray(grid.point_order, jnp.int32)
            # ... and padded to the bucket for the combined bipartite order,
            # so the (query | data) order array keeps one shape per bucket
            # across snapshot swaps (pad rows are never decoded)
            self.point_order_padded = jnp.asarray(
                pad_axis0(grid.point_order.astype(np.int64), self.point_rows),
                jnp.int32,
            )
            self.tiles = ops.make_tiles_device(
                jnp.asarray(grid.pts_sorted),
                self.tile_start,
                self.tile_len,
                tile_size=config.tile_size,
                dim_block=config.dim_block,
            )
        else:
            self.tiles = None
            self.tile_len = None
            self.tile_start = None
            self.point_order = None
            self.point_order_padded = None

    # -- constructors ------------------------------------------------------

    @classmethod
    def build(
        cls,
        d: np.ndarray,
        config: SelfJoinConfig,
        eps: Optional[float] = None,
        *,
        perm=_AUTO_PERM,
        min_tile_rows: int = 1,
        min_point_rows: int = 1,
        min_dense_rows: int = 1,
    ) -> "GridSnapshot":
        """Full index build: REORDER (unless ``perm`` is given), grid, plan.

        ``perm=_AUTO_PERM`` computes the variance permutation per
        ``config.reorder``; passing an explicit permutation (or ``None``)
        reuses a previous snapshot's frame -- ``compact`` does this so the
        rebuilt index bins points identically to the one it replaces.
        """
        pts = np.ascontiguousarray(np.asarray(d, dtype=np.float32))
        eps = config.eps if eps is None else float(eps)
        if isinstance(perm, str) and perm == _AUTO_PERM:
            perm = None
            if config.reorder and pts.shape[0]:
                _, perm = variance_reorder(pts, config.sample_frac)
        elif perm is not None:
            perm = np.asarray(perm)
        work = pts if perm is None else apply_reorder(pts, perm)
        grid = plan = None
        index_eps = None
        if pts.shape[0]:
            grid = build_grid(work, eps, config.k)  # eps=0-safe (unit bins)
            plan = build_tile_plan(grid, config.tile_size, config.sortidu)
            index_eps = float(eps)
        return cls(
            config, pts, perm, work, index_eps, grid, plan,
            min_tile_rows=min_tile_rows,
            min_point_rows=min_point_rows,
            min_dense_rows=min_dense_rows,
        )

    @classmethod
    def from_arrays(
        cls,
        pts: np.ndarray,
        perm: Optional[np.ndarray],
        grid: Optional[GridIndex],
        plan: Optional[TilePlan],
        index_eps: Optional[float],
        config: SelfJoinConfig,
        *,
        min_tile_rows: int = 1,
        min_point_rows: int = 1,
        min_dense_rows: int = 1,
    ) -> "GridSnapshot":
        """Snapshot over already-built arrays: only device placement runs.

        The persistence re-entry path (``SimilarityIndex.load`` via
        ``SelfJoinEngine.from_prebuilt``): a restarted server re-places the
        saved (perm, grid, plan) triple and is bit-identical to the process
        that saved it.
        """
        pts = np.ascontiguousarray(np.asarray(pts, dtype=np.float32))
        perm = None if perm is None else np.asarray(perm)
        work = pts if perm is None else apply_reorder(pts, perm)
        return cls(
            config, pts, perm, work, index_eps, grid, plan,
            min_tile_rows=min_tile_rows,
            min_point_rows=min_point_rows,
            min_dense_rows=min_dense_rows,
        )

    def rebuilt(self, eps: float) -> "GridSnapshot":
        """Same points, same permutation, new grid at ``eps``.

        Buckets are floored at this snapshot's, so growing the radius (the
        engine's transparent rebuild, or a temporary over-radius serving
        snapshot) never SHRINKS a device shape out from under a warm
        executable.
        """
        return GridSnapshot.build(
            self.pts, self.config, eps,
            perm=self.perm,
            min_tile_rows=self.tile_rows,
            min_point_rows=self.point_rows,
            min_dense_rows=self.dense_rows,
        )

    # -- derived views -----------------------------------------------------

    @property
    def n_pad(self) -> int:
        """Padded dimension count of the tile layout (n -> dim_block multiple)."""
        db = self.config.dim_block
        return ((self.num_dims + db - 1) // db) * db

    @property
    def num_dim_blocks(self) -> int:
        return self.tiles.shape[2] // self.config.dim_block

    @property
    def data_bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-dimension (min, max) of the snapshot points, reordered frame."""
        if self.grid is not None:
            return self.grid.data_bounds
        z = np.zeros(self.num_dims, np.float64)
        return z, z

    def chunks(self, chunk: int) -> List[Tuple[jax.Array, jax.Array, int]]:
        """Padded device chunks of the self-join candidate pair list."""
        return _chunk_list(
            self.plan.pair_a, self.plan.pair_b, chunk, self._chunk_cache
        )

    def dense_tables(self) -> DenseTables:
        """Build (lazily, once per snapshot) the dense-tier tables."""
        if self._dense is None:
            cfg = self.config
            plan = make_dense_plan(self.num_points, cfg.tile_size)
            start = pad_axis0(plan.tile_start, self.dense_rows)
            length = pad_axis0(plan.tile_len, self.dense_rows)
            tiles = ops.make_tiles_device(
                jnp.asarray(self.grid.pts_sorted),
                jnp.asarray(start, jnp.int32),
                jnp.asarray(length, jnp.int32),
                tile_size=cfg.tile_size,
                dim_block=cfg.dim_block,
            )
            self._dense = DenseTables(
                plan=plan,
                tiles=tiles,
                tile_len=jnp.asarray(length, jnp.int32),
                tile_start=jnp.asarray(start, jnp.int32),
            )
        return self._dense

    def packed_tile_table(self, num_tiles: int):
        """Host-side ``(tiles, tile_len)`` padded to ``num_tiles`` rows.

        The fused ring payload (``core/dist_engine.py``): every shard's
        tile table is padded to the fleet-wide maximum so all ring
        positions trace with one shape; padding rows carry ``tile_len ==
        0`` (the sentinel the chunk program's validity mask already
        understands), so they contribute nothing wherever a padded pair
        list references them.
        """
        t = self.config.tile_size
        tiles = np.zeros((num_tiles, t, self.n_pad), np.float32)
        tile_len = np.zeros(num_tiles, np.int32)
        if self.plan is not None and self.plan.num_tiles:
            real, lens = ops.make_tiles(
                self.grid.pts_sorted,
                self.plan.tile_start,
                self.plan.tile_len,
                t,
                self.config.dim_block,
            )
            tiles[: real.shape[0]] = real
            tile_len[: lens.shape[0]] = lens
        return tiles, tile_len
