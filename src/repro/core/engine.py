"""Device-resident self-join engine (DESIGN.md #1.5, #10).

``SelfJoinEngine`` keeps the entire hot loop of GPU-Join (Gowanlock &
Karsin 2018, Alg. 1 lines 11-19 plus constructNeighborTable) on the
accelerator.  Only index *construction* (REORDER, grid build, tile-pair
planning) runs on the host, exactly as in the paper; everything downstream
is jitted device code:

  tiling       -- ``ops.make_tiles_device``: one vectorized gather replaces
                  the per-tile host loop;
  evaluation   -- the tile distance kernel (Pallas or jnp backend), SHORTC
                  dimension-blocked, eps as a traced scalar;
  count scatter-- per-point neighbour counts accumulate via an in-jit
                  scatter-add over the grid-sorted layout (the host
                  ``np.add.at`` is gone);
  pairs        -- device-side stream compaction (prefix-sum over the hit
                  mask) into a preallocated ``max_pairs`` buffer with an
                  overflow flag (the host ``np.nonzero`` is gone), already
                  mapped to original point ids via a device gather.

Snapshot/executable split (DESIGN.md #10): every piece of data-derived
state -- points, REORDER permutation, grid, tile plan, device tables,
dense tables -- lives in a frozen ``GridSnapshot`` (``core/snapshot.py``);
the engine holds only configuration and the compiled chunk programs.
Programs are keyed by (mode, chunk shape, backend), never by data
identity, so ``swap_snapshot`` -- one reference assignment -- replaces the
dataset behind a warm engine without invalidating a single executable, as
long as the new snapshot keeps the old shape buckets (it does, by the
floor-carrying contract of ``GridSnapshot.rebuilt`` and the mutable
index's ``compact``).

Chunking / compilation-caching contract: the candidate tile-pair list is
processed in fixed-size, zero-padded chunks; eps, the chunk's real length,
and the running (buffer, offset, overflow, counts) state are all traced, so
XLA compiles **at most one program per (mode, chunk shape)** and the Python
chunk loop dispatches that same executable -- no host compute, no host
transfers inside the loop.  The executables are module-level, shared by
every engine instance; a multi-eps sweep recompiles nothing.

``repro.core.selfjoin.self_join`` is a thin wrapper over this class.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batching as batching_mod
from repro.core import cost as cost_mod
from repro.core.grid import (
    GridIndex,
    QueryTilePlan,
    TilePlan,
    build_query_tile_plan,
    pad_axis0,
)
from repro.core.reorder import apply_reorder
from repro.core.snapshot import (  # noqa: F401  (re-exported compat names)
    DenseTables,
    GridSnapshot,
    _chunk_list,
    make_dense_plan,
)
from repro.core.types import (
    EngineConfig,
    SelfJoinConfig,
    SelfJoinResult,
    SelfJoinStats,
)
from repro import obs
from repro.kernels import ops

_MAX_AUTO_GROW = 8  # doublings before giving up on an auto-sized buffer


# ---------------------------------------------------------------------------
# Device programs.  Module-level so every engine instance shares one jit
# cache; all dynamic state is passed (and returned) as traced values.
# ---------------------------------------------------------------------------


def _chunk_validity(tile_len, tile_start, pa, real, t):
    """(pair_valid (C,), row validity (C,T), scatter rows (C,T))."""
    c = pa.shape[0]
    lane = jnp.arange(t, dtype=jnp.int32)
    pair_valid = jnp.arange(c, dtype=jnp.int32) < real
    valid = pair_valid[:, None] & (lane[None, :] < tile_len[pa][:, None])
    idx = tile_start[pa][:, None] + lane[None, :]
    return pair_valid, valid, idx


def count_chunk_step(
    counts_sorted,  # (N,) int32 running per-point counts, grid-sorted
    skipped_tot,    # ()  int32 running SHORTC skipped-block total
    tiles,          # (num_tiles, T, n_pad) f32
    tile_len,       # (num_tiles,) int32
    tile_start,     # (num_tiles,) int32
    pa, pb,         # (C,) int32 padded chunk of the candidate pair list
    real,           # () int32 valid prefix of the chunk
    eps,            # () f32 traced search radius
    *,
    dim_block, shortc, backend, interpret,
):
    """One counts-mode chunk: evaluate + scatter-add, fully traceable.

    This is the body shared by the jitted single-device program below and
    the fused distributed ring program (``core/dist_engine.py``), where the
    tile tables themselves are traced values rotating through ``ppermute``
    -- so nothing here may assume host-side (concrete) inputs.
    """
    counts, skipped = ops.eval_tile_pairs(
        tiles, tile_len, pa, pb, eps,
        dim_block=dim_block, shortc=shortc, backend=backend,
        interpret=interpret,
    )
    t = tiles.shape[1]
    n = counts_sorted.shape[0]
    pair_valid, valid, idx = _chunk_validity(tile_len, tile_start, pa, real, t)
    idx = jnp.where(valid, idx, n)  # out-of-range -> dropped
    counts_sorted = counts_sorted.at[idx].add(
        jnp.where(valid, counts, 0), mode="drop"
    )
    skipped_tot = skipped_tot + jnp.where(pair_valid, skipped, 0).sum()
    return counts_sorted, skipped_tot


@functools.wraps(count_chunk_step)
def _count_chunk_traced(*args, **kwargs):
    # Runs only while XLA traces (cache misses), so the obs event stream
    # distinguishes "compiled a new count program" from warm dispatches.
    obs.event("engine.trace", "compile", program="count_chunk")
    return count_chunk_step(*args, **kwargs)


_count_chunk_program = functools.partial(
    jax.jit, static_argnames=("dim_block", "shortc", "backend", "interpret")
)(_count_chunk_traced)


def pairs_chunk_step(
    buf,            # (cap + hit_cap, 2) int32 result buffer, original ids
    offset,         # ()  int32 pairs found so far (may exceed cap)
    max_chunk_hits, # ()  int32 largest per-chunk hit count seen
    tiles, tile_len, tile_start,
    point_order,    # (N,) int32 grid-sorted -> original id
    pa, pb, real, eps,
    *,
    hit_cap, dim_block, backend, interpret,
):
    """One pairs-mode chunk: evaluate + compact into ``buf``, fully on device.

    Like ``count_chunk_step`` this is the un-jitted body, so callers that
    need their own trace accounting (the serving tier, ``repro.join``) can
    wrap it in their own ``jax.jit``; the module-level jitted program below
    serves the engine.  For a *bipartite* chunk, ``point_order`` is the
    combined (query | data) position->original-id map and ``tile_start`` the
    combined position table of ``SelfJoinEngine.prepare_query`` -- A-side
    rows then decode to query ids and B-side rows to data ids.  The fused
    distributed ring (``core/dist_engine.py``) runs this same body inside
    its one ``shard_map`` program: the (buf, offset, max_chunk_hits) triple
    becomes the per-worker ring carry and the decode tables are traced
    values rotating through ``ppermute``, so nothing here may assume
    host-side (concrete) inputs.

    Compaction is rank-select, not scatter (scatter over the full C*T*T
    mask serializes badly on CPU XLA): a row-wise prefix sum over the hit
    mask (C independent chains, then a tiny base scan) gives every hit its
    global output rank; ``searchsorted`` recovers the flat positions of
    ranks 1..hit_cap, and the gathered (a, b) rows land in ``buf`` as one
    contiguous ``dynamic_update_slice`` block at ``offset``.  Ranks past
    the chunk's true hit count select clamped garbage that the next
    chunk's block (or the final slice) overwrites.  Nothing is lost
    silently: ``offset`` advances by the exact hit count, and the host
    driver retries with a larger buffer when ``offset`` exceeds the
    capacity, or with a larger ``hit_cap`` when ``max_chunk_hits`` says a
    single chunk outgrew the rank window.  Per-point counts are *not*
    accumulated here -- they fall out of the finished buffer in one
    scatter (``_counts_from_pairs``).
    """
    _, _, mask = ops.eval_tile_pairs(
        tiles, tile_len, pa, pb, eps,
        dim_block=dim_block, shortc=True, backend=backend,
        return_mask=True, interpret=interpret,
    )
    t = tiles.shape[1]
    c = pa.shape[0]
    cap = buf.shape[0] - hit_cap

    pair_valid = jnp.arange(c, dtype=jnp.int32) < real
    hits = (mask.astype(jnp.bool_) & pair_valid[:, None, None]).reshape(
        c, t * t
    ).astype(jnp.int32)
    row_cum = jnp.cumsum(hits, axis=1)            # C independent prefix sums
    row_tot = row_cum[:, -1]
    base = jnp.cumsum(row_tot) - row_tot          # (C,) exclusive
    cum = (row_cum + base[:, None]).reshape(-1)   # global inclusive ranks
    nh = row_tot.sum(dtype=jnp.int32)
    ranks = jnp.arange(1, hit_cap + 1, dtype=jnp.int32)
    hit_idx = jnp.minimum(jnp.searchsorted(cum, ranks), c * t * t - 1)
    p_ = hit_idx // (t * t)
    i_ = (hit_idx // t) % t
    j_ = hit_idx % t
    a_orig = point_order[tile_start[pa[p_]] + i_]
    b_orig = point_order[tile_start[pb[p_]] + j_]
    block = jnp.stack([a_orig, b_orig], axis=1)           # (hit_cap, 2)
    woff = jnp.minimum(offset, cap)  # post-overflow blocks land in padding
    buf = jax.lax.dynamic_update_slice(buf, block, (woff, jnp.int32(0)))

    offset = offset + nh
    max_chunk_hits = jnp.maximum(max_chunk_hits, nh)
    return buf, offset, max_chunk_hits


@functools.wraps(pairs_chunk_step)
def _pairs_chunk_traced(*args, **kwargs):
    obs.event("engine.trace", "compile", program="pairs_chunk")
    return pairs_chunk_step(*args, **kwargs)


_pairs_chunk_program = functools.partial(
    jax.jit, static_argnames=("hit_cap", "dim_block", "backend", "interpret")
)(_pairs_chunk_traced)


@jax.jit
def _counts_from_pairs(counts0, buf, num):
    """Per-point counts from the compacted pair buffer (original order)."""
    rows = jnp.arange(buf.shape[0], dtype=jnp.int32)
    a = jnp.where(rows < num, buf[:, 0], counts0.shape[0])
    return counts0.at[a].add(1, mode="drop")


@jax.jit
def _unsort_counts(counts_sorted, point_order):
    """Grid-sorted counts -> original point order (device scatter)."""
    return jnp.zeros_like(counts_sorted).at[point_order].set(counts_sorted)


# ---------------------------------------------------------------------------
# The bipartite query-plan API (DESIGN.md #8).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QueryPlanTables:
    """Device-ready combined (query | data) tables for one bipartite batch.

    Produced by ``SelfJoinEngine.prepare_query`` and consumed by three
    callers with one layout:

      * ``SelfJoinEngine.count_query`` (the distributed tier's per-round
        local join) runs the count chunk program over it;
      * the serving tier (``repro.join.QueryService``) runs its own
        trace-counted count *and* pairs programs over it, with
        ``pad_queries_to`` rounding the query side up to a shape bucket so a
        request stream reuses a bounded set of executables;
      * the fused distributed packer keeps its own padding, but shares the
        underlying ``build_query_plan`` host plan.

    Layout contract: positions ``[0, n_slots)`` are query rows in q-sorted
    order (real rows first, zero padding after), positions ``[n_slots,
    n_slots + point_rows)`` are the engine's grid-sorted data points padded
    to the snapshot's pow2 ``point_rows`` bucket (pad positions are never
    referenced by a valid lane or pair list).  ``tile_start`` and ``order``
    address that combined position space, so the *same* arrays serve counts
    mode (A-side scatter into a ``(n_slots,)`` vector; B-side starts never
    read below ``n_slots``) and pairs mode (both sides decode through
    ``order`` to original query rows / data ids).
    """

    eps: float                     # radius the plan was built for
    nq: int                        # real query rows
    n_slots: int                   # padded query-position space (>= nq)
    qplan: QueryTilePlan           # the host-side plan (stats + q_order live here)
    tiles: jax.Array               # (q_tile_rows + d_tile_rows, T, n_pad) f32
    tile_len: jax.Array            # (q_tile_rows + d_tile_rows,) int32
    tile_start: jax.Array          # combined position space (B side + n_slots)
    order: jax.Array               # (n_slots + point_rows,) int32 position -> id
    pair_a: np.ndarray             # (P,) int32 combined-table A (query-tile) index
    pair_b: np.ndarray             # (P,) int32 combined-table B (data-tile) index
    execution: str = "indexed"     # tier the tables realize: "indexed" | "dense"
    cost_indexed: float = 0.0      # cost model's indexed-tier estimate
    cost_dense: float = 0.0        # cost model's dense-tier estimate
    num_candidates: int = 0        # point comparisons this tier will evaluate
    _chunk_cache: Dict[int, list] = dataclasses.field(default_factory=dict)

    @property
    def num_pairs(self) -> int:
        return int(self.pair_a.shape[0])

    def chunks(self, chunk: int) -> List[Tuple[jax.Array, jax.Array, int]]:
        """Padded device chunks of the candidate pair list, cached per size."""
        return _chunk_list(self.pair_a, self.pair_b, chunk, self._chunk_cache)


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------


class SelfJoinEngine:
    """Reusable device-resident self-join over one dataset snapshot.

    Builds a ``GridSnapshot`` once (at construction, for ``config.eps``);
    ``count()`` / ``pairs()`` / ``query()`` reuse both the snapshot and the
    compiled chunk programs; querying a *larger* eps than the snapshot was
    built for transparently swaps in a rebuilt snapshot (a smaller eps
    reuses it -- the candidate set is a superset, and the distance filter
    runs at the queried eps).  ``swap_snapshot`` is the mutable-index
    re-entry point: one reference assignment replaces the dataset and
    invalidates no compiled program.

    ``eps == 0`` is supported (degenerate join: duplicates + self); the
    grid is then binned at unit width, which is correct for any radius
    not exceeding it.  Note the fp32 matmul-form numerics (DESIGN.md #6):
    at eps near 0, exact-duplicate/self matches are only guaranteed on
    quantized coordinates (e.g. a 1/64 grid); on arbitrary fp32 data the
    rounding of ``|a|^2 + |b|^2 - 2ab`` can exceed an eps^2 of ~1e-8.
    """

    def __init__(
        self,
        d: np.ndarray,
        config: SelfJoinConfig,
        engine_config: Optional[EngineConfig] = None,
    ):
        self.config = config
        self.engine = engine_config or EngineConfig()
        with obs.span(
            "engine.snapshot_build", "plan", n=int(np.asarray(d).shape[0])
        ):
            self.snapshot = GridSnapshot.build(d, config)

    @classmethod
    def from_snapshot(
        cls,
        snapshot: GridSnapshot,
        engine_config: Optional[EngineConfig] = None,
    ) -> "SelfJoinEngine":
        """Engine over an existing snapshot (no host build at all)."""
        self = object.__new__(cls)
        self.config = snapshot.config
        self.engine = engine_config or EngineConfig()
        self.snapshot = snapshot
        return self

    @classmethod
    def from_prebuilt(
        cls,
        pts: np.ndarray,
        perm: Optional[np.ndarray],
        grid: Optional[GridIndex],
        plan: Optional[TilePlan],
        index_eps: Optional[float],
        config: SelfJoinConfig,
        engine_config: Optional[EngineConfig] = None,
    ) -> "SelfJoinEngine":
        """Engine over an already-built index: no REORDER, no grid build.

        The persistence path of ``repro.join.SimilarityIndex``: a server
        restart loads the saved (perm, grid, plan) triple and only the
        device placement runs again, so the restarted engine is
        bit-identical to the one that was saved.
        """
        return cls.from_snapshot(
            GridSnapshot.from_arrays(pts, perm, grid, plan, index_eps, config),
            engine_config,
        )

    # -- snapshot management ----------------------------------------------

    def swap_snapshot(self, snapshot: GridSnapshot) -> None:
        """Atomically replace the data snapshot behind the warm executables.

        One reference assignment: requests that pinned the previous
        snapshot keep serving it unchanged, and no compiled program is
        invalidated (programs key on shapes, and the snapshot's pow2 row
        buckets keep shapes stable across a compact/rebuild of the same
        bucket).
        """
        if snapshot.config != self.config:
            raise ValueError(
                "snapshot was built under a different SelfJoinConfig"
            )
        self.snapshot = snapshot

    def snapshot_for(self, eps: float) -> GridSnapshot:
        """A snapshot whose index covers ``eps``, WITHOUT swapping.

        The serving tier's epoch pinning: an over-radius request builds a
        temporary rebuilt snapshot, serves from it, and drops it -- the
        engine's resident snapshot (and every warm executable keyed to its
        buckets) is untouched.
        """
        snap = self.snapshot
        if snap.num_points == 0 or (
            snap.index_eps is not None and eps <= snap.index_eps
        ):
            return snap
        with obs.span(
            "engine.snapshot_rebuild", "plan",
            eps=eps, n=snap.num_points, pinned=True,
        ):
            return snap.rebuilt(eps)

    def _ensure_index(self, eps: float) -> None:
        snap = self.snapshot
        if snap.num_points == 0:
            return
        if snap.index_eps is None or eps > snap.index_eps:
            with obs.span(
                "engine.snapshot_rebuild", "plan", eps=eps, n=snap.num_points
            ):
                self.swap_snapshot(snap.rebuilt(eps))

    # -- delegating views (compat surface over the snapshot) ---------------

    @property
    def num_points(self) -> int:
        return self.snapshot.num_points

    @property
    def num_dims(self) -> int:
        return self.snapshot.num_dims

    @property
    def grid(self) -> Optional[GridIndex]:
        return self.snapshot.grid

    @property
    def plan(self) -> Optional[TilePlan]:
        return self.snapshot.plan

    @property
    def n_pad(self) -> int:
        """Padded dimension count of the tile layout (n -> dim_block multiple)."""
        return self.snapshot.n_pad

    @property
    def _pts(self) -> np.ndarray:
        return self.snapshot.pts

    @property
    def _perm(self) -> Optional[np.ndarray]:
        return self.snapshot.perm

    @property
    def _index_eps(self) -> Optional[float]:
        return self.snapshot.index_eps

    @property
    def _tiles(self) -> jax.Array:
        return self.snapshot.tiles

    @property
    def _tile_len(self) -> jax.Array:
        return self.snapshot.tile_len

    @property
    def _tile_start(self) -> jax.Array:
        return self.snapshot.tile_start

    @property
    def _point_order(self) -> jax.Array:
        return self.snapshot.point_order

    @property
    def _num_dim_blocks(self) -> int:
        return self.snapshot.num_dim_blocks

    def resolve_execution(
        self, eps: Optional[float] = None,
        snapshot: Optional[GridSnapshot] = None,
    ) -> cost_mod.TierDecision:
        """Cost-model tier decision for a self-join at ``eps`` (DESIGN.md #9).

        Always computes both estimates (even under a forced mode) so stats
        record what the model thought alongside what actually ran.
        """
        eps = self.config.eps if eps is None else float(eps)
        cfg = self.config
        if snapshot is None:
            if self.num_points == 0:
                return cost_mod.decide(0.0, 0.0, cfg.execution)
            self._ensure_index(eps)
            snapshot = self.snapshot
        if snapshot.num_points == 0:
            return cost_mod.decide(0.0, 0.0, cfg.execution)
        ci = cost_mod.indexed_join_cost(
            snapshot.plan.num_pairs, snapshot.plan.num_candidates,
            cfg.tile_size, snapshot.n_pad,
        )
        cd = cost_mod.dense_join_cost(
            snapshot.num_points, snapshot.num_points,
            cfg.tile_size, snapshot.n_pad,
        )
        return cost_mod.decide(ci, cd, cfg.execution)

    def _base_stats(self, eps: float, snap: GridSnapshot) -> SelfJoinStats:
        stats = SelfJoinStats(
            num_points=snap.num_points,
            num_dims=snap.num_dims,
            k=min(self.config.k, snap.num_dims),
        )
        if snap.plan is not None:
            stats.num_nonempty_cells = snap.grid.num_cells
            stats.num_tiles = snap.plan.num_tiles
            stats.num_tile_pairs_total = snap.plan.num_tile_pairs_total
            stats.num_tile_pairs_evaluated = snap.plan.num_pairs
            stats.num_candidates = snap.plan.num_candidates
            stats.num_candidates_dense = snap.num_points * snap.num_points
        return stats

    @staticmethod
    def _record_decision(stats: SelfJoinStats, dec: cost_mod.TierDecision) -> None:
        stats.execution = dec.execution
        stats.cost_indexed = dec.cost_indexed
        stats.cost_dense = dec.cost_dense

    def build_query_plan(
        self,
        q_pts: np.ndarray,
        eps: Optional[float] = None,
        snapshot: Optional[GridSnapshot] = None,
    ):
        """Bipartite Q-tile x D-tile plan for ``q_pts`` against this index.

        ``q_pts`` is in ORIGINAL coordinates; the engine applies its own
        REORDER permutation.  Shared by ``count_query`` and the fused
        distributed ring packer (``core/dist_engine.py``), which needs the
        plan host-side to pad it into the uniform per-round tables.
        With an explicit ``snapshot`` the plan is built against it (the
        serving tier's pinned epoch); otherwise the engine's resident
        snapshot is used, rebuilt if ``eps`` outgrows it.  Returns ``None``
        when the snapshot indexes no points (every candidate list would be
        empty).
        """
        eps = self.config.eps if eps is None else float(eps)
        if snapshot is None:
            if self.num_points == 0:
                return None
            self._ensure_index(eps)
            snapshot = self.snapshot
        if snapshot.num_points == 0:
            return None
        q_work = (
            apply_reorder(q_pts, snapshot.perm)
            if snapshot.perm is not None else q_pts
        )
        with obs.span(
            "engine.build_query_plan", "plan",
            nq=int(q_work.shape[0]), eps=eps,
        ):
            return build_query_tile_plan(
                snapshot.grid, snapshot.plan, q_work, self.config.sortidu
            )

    def prepare_query(
        self,
        q_pts: np.ndarray,
        eps: Optional[float] = None,
        *,
        pad_queries_to: Optional[int] = None,
        snapshot: Optional[GridSnapshot] = None,
    ) -> Optional[QueryPlanTables]:
        """Build the device-ready combined (query | data) tables for ``q_pts``.

        The query-plan API (DESIGN.md #8): everything between the host-side
        ``build_query_plan`` and the chunk programs -- query tiling on
        device, the concatenated (Q | D) tile table, the combined
        position->original-id map, and the B-side index offset -- shared by
        ``count_query`` and the serving tier.

        ``pad_queries_to`` rounds the *query side* of every device array up
        to that many rows (q-sorted points, query tiles, and the scatter
        target all pad to the same bucket; padding tiles carry length 0 and
        padded positions are never referenced by a valid lane), so all
        batches in the same bucket share one compiled executable.  The data
        side is padded by the snapshot's own pow2 buckets, so tables built
        against two snapshots of the same buckets share shapes too.
        ``snapshot`` pins an explicit snapshot (no engine mutation); by
        default the resident one serves, rebuilt if ``eps`` outgrows it.
        Returns ``None`` when either side is empty.
        """
        with obs.span(
            "engine.prepare_query", "plan", nq=int(np.asarray(q_pts).shape[0])
        ):
            return self._prepare_query_impl(
                q_pts, eps, pad_queries_to=pad_queries_to, snapshot=snapshot
            )

    def _prepare_query_impl(
        self,
        q_pts: np.ndarray,
        eps: Optional[float] = None,
        *,
        pad_queries_to: Optional[int] = None,
        snapshot: Optional[GridSnapshot] = None,
    ) -> Optional[QueryPlanTables]:
        eps = self.config.eps if eps is None else float(eps)
        q_pts = np.ascontiguousarray(np.asarray(q_pts, dtype=np.float32))
        nq = q_pts.shape[0]
        if snapshot is None:
            if nq == 0 or self.num_points == 0:
                return None
            self._ensure_index(eps)
            snapshot = self.snapshot
        snap = snapshot
        if nq == 0 or snap.num_points == 0:
            return None
        qplan = self.build_query_plan(q_pts, eps, snapshot=snap)
        cfg = self.config
        n_slots = nq if pad_queries_to is None else int(pad_queries_to)
        if n_slots < nq:
            raise ValueError(
                f"pad_queries_to={n_slots} smaller than the batch ({nq})"
            )
        # cost-model tier dispatch (DESIGN.md #9): the indexed estimate comes
        # from the grid probe that just ran, the dense estimate from the
        # batch shape alone.  Both tiers share q_sorted / q_order (the dense
        # tier only re-tiles the already-sorted rows sequentially).
        dec = cost_mod.decide(
            cost_mod.indexed_join_cost(
                qplan.num_pairs, qplan.num_candidates, cfg.tile_size,
                snap.n_pad,
            ),
            cost_mod.dense_join_cost(
                nq, snap.num_points, cfg.tile_size, snap.n_pad
            ),
            cfg.execution,
        )
        t = cfg.tile_size
        # every cell holds >= 1 point, so num_q_tiles <= nq <= n_slots: one
        # bucket dimension pads the q-sorted rows AND the q-tile rows
        qt_rows = n_slots
        if pad_queries_to is None:
            qt_rows = qplan.num_q_tiles if dec.execution == "indexed" else -(-nq // t)
        q_sorted = pad_axis0(qplan.q_sorted, n_slots)
        if dec.execution == "dense":
            dt = snap.dense_tables()
            q_start = (np.arange(qt_rows, dtype=np.int64) * t).astype(np.int32)
            q_len = np.clip(nq - q_start.astype(np.int64), 0, t).astype(np.int32)
            nqt = -(-nq // t)  # real (non-empty) query tiles
            pair_a = np.repeat(np.arange(nqt, dtype=np.int64), dt.plan.num_tiles)
            pair_d = np.tile(np.arange(dt.plan.num_tiles, dtype=np.int64), nqt)
            d_tiles, d_len, d_start = dt.tiles, dt.tile_len, dt.tile_start
            num_candidates = nq * snap.num_points
        else:
            q_start = pad_axis0(qplan.q_tile_start, qt_rows)
            q_len = pad_axis0(qplan.q_tile_len, qt_rows)
            pair_a = qplan.pair_q.astype(np.int64)
            pair_d = qplan.pair_d.astype(np.int64)
            d_tiles, d_len, d_start = snap.tiles, snap.tile_len, snap.tile_start
            num_candidates = qplan.num_candidates
        q_tiles = ops.make_tiles_device(
            jnp.asarray(q_sorted),
            jnp.asarray(q_start, jnp.int32),
            jnp.asarray(q_len, jnp.int32),
            tile_size=cfg.tile_size,
            dim_block=cfg.dim_block,
        )
        tiles = jnp.concatenate([q_tiles, d_tiles], axis=0)
        tile_len = jnp.concatenate([jnp.asarray(q_len, jnp.int32), d_len])
        tile_start = jnp.concatenate(
            [jnp.asarray(q_start, jnp.int32), d_start + n_slots]
        )
        # position -> original id: query rows first (pad rows are never
        # addressed by a valid lane; their fill value is irrelevant), then
        # the data points' grid-sort permutation, padded to the snapshot's
        # point_rows bucket so the shape survives snapshot swaps
        order = jnp.concatenate(
            [
                jnp.asarray(
                    pad_axis0(qplan.q_order.astype(np.int64), n_slots), jnp.int32
                ),
                snap.point_order_padded,
            ]
        )
        pair_b = (pair_d + qt_rows).astype(np.int32)
        return QueryPlanTables(
            eps=eps,
            nq=nq,
            n_slots=n_slots,
            qplan=qplan,
            tiles=tiles,
            tile_len=tile_len,
            tile_start=tile_start,
            order=order,
            pair_a=pair_a.astype(np.int32),
            pair_b=pair_b,
            execution=dec.execution,
            cost_indexed=dec.cost_indexed,
            cost_dense=dec.cost_dense,
            num_candidates=num_candidates,
        )

    def packed_tile_table(self, num_tiles: int):
        """Host tile table padded to ``num_tiles`` rows (delegates to the
        snapshot; kept for callers that hold only the engine)."""
        return self.snapshot.packed_tile_table(num_tiles)

    # -- queries ----------------------------------------------------------

    def _self_tables(self, dec: cost_mod.TierDecision, snap: GridSnapshot):
        """Device tables of the tier ``dec`` chose, one tuple for both modes.

        Returns ``(tiles, tile_len, tile_start, chunks_fn, plan, backend,
        shortc)``.  Both tiers address the same grid-sorted point space (the
        dense tier only re-tiles it), so the scatter epilogues and
        ``_unsort_counts`` are tier-independent.
        """
        cfg = self.config
        if dec.execution == "dense":
            dt = snap.dense_tables()
            return (
                dt.tiles, dt.tile_len, dt.tile_start, dt.chunks, dt.plan,
                ops.backend_name("dense", cfg.use_pallas), False,
            )
        return (
            snap.tiles, snap.tile_len, snap.tile_start, snap.chunks,
            snap.plan, ops.backend_name("indexed", cfg.use_pallas), cfg.shortc,
        )

    def count(self, eps: Optional[float] = None) -> SelfJoinResult:
        """Per-point neighbour counts (original order); no pair buffer."""
        eps = self.config.eps if eps is None else float(eps)
        if self.num_points == 0:
            return SelfJoinResult(
                counts=np.zeros(0, np.int64),
                stats=self._base_stats(eps, self.snapshot),
            )
        self._ensure_index(eps)
        snap = self.snapshot
        cfg, eng = self.config, self.engine
        dec = self.resolve_execution(eps)
        tiles, tile_len, tile_start, chunks, plan, backend, shortc = (
            self._self_tables(dec, snap)
        )
        stats = self._base_stats(eps, snap)
        self._record_decision(stats, dec)
        if dec.execution == "dense":
            stats.num_tile_pairs_evaluated = plan.num_pairs
            stats.num_candidates = plan.num_candidates

        counts_sorted = jnp.zeros(snap.num_points, jnp.int32)
        skipped_tot = jnp.zeros((), jnp.int32)
        with obs.span(
            "engine.count", "join",
            n=snap.num_points, eps=eps, tier=dec.execution,
        ):
            for pa, pb, real in chunks(eng.count_chunk):
                with obs.span("engine.count.chunk", "dispatch"):
                    counts_sorted, skipped_tot = _count_chunk_program(
                        counts_sorted, skipped_tot,
                        tiles, tile_len, tile_start,
                        pa, pb, real, eps,
                        dim_block=cfg.dim_block, shortc=shortc,
                        backend=backend,
                        interpret=eng.interpret,
                    )
                stats.num_chunks += 1
                stats.num_device_dispatches += 1
            counts = np.asarray(
                _unsort_counts(counts_sorted, snap.point_order)
            ).astype(np.int64)
        stats.num_results = int(counts.sum())
        stats.dim_blocks_skipped = int(skipped_tot)
        stats.dim_blocks_total = plan.num_pairs * snap.num_dim_blocks
        obs.mirror_selfjoin_stats(stats, path="engine", mode="count")
        return SelfJoinResult(counts=counts, stats=stats)

    def count_query(
        self,
        q: np.ndarray,
        eps: Optional[float] = None,
        snapshot: Optional[GridSnapshot] = None,
    ) -> SelfJoinResult:
        """Per-query-point counts of indexed points within eps of each q.

        The bipartite sub-plan of the distributed tier (DESIGN.md #7):
        external query points are binned into this engine's grid, tiled, and
        each (query tile, adjacent data tile) candidate pair runs through the
        same chunked count program as the self-join -- index filtering, SHORTC
        and SORTIDU included.  ``q`` is given in ORIGINAL coordinates (the
        engine applies its own REORDER permutation); counts come back in
        ``q``'s row order.  Self-joining the engine's own dataset equals
        ``count()``:  ``count_query(d).counts == count().counts``.
        """
        eps = self.config.eps if eps is None else float(eps)
        q_pts = np.ascontiguousarray(np.asarray(q, dtype=np.float32))
        nq = q_pts.shape[0]
        cfg, eng = self.config, self.engine
        tab = self.prepare_query(q_pts, eps, snapshot=snapshot)
        snap = snapshot if snapshot is not None else self.snapshot
        if tab is None:
            return SelfJoinResult(
                counts=np.zeros(nq, np.int64),
                stats=self._base_stats(eps, snap),
            )
        qplan = tab.qplan

        stats = self._base_stats(eps, snap)
        stats.num_points = nq
        stats.num_tile_pairs_total = qplan.num_tile_pairs_total
        stats.num_tile_pairs_evaluated = tab.num_pairs
        stats.num_candidates = tab.num_candidates
        stats.num_candidates_dense = nq * snap.num_points
        stats.num_tiles = int(tab.tiles.shape[0])
        stats.execution = tab.execution
        stats.cost_indexed = tab.cost_indexed
        stats.cost_dense = tab.cost_dense
        backend = ops.backend_name(tab.execution, cfg.use_pallas)
        shortc = cfg.shortc and tab.execution == "indexed"

        counts_sorted = jnp.zeros(tab.n_slots, jnp.int32)
        skipped_tot = jnp.zeros((), jnp.int32)
        with obs.span(
            "engine.count_query", "join",
            nq=nq, eps=eps, tier=tab.execution,
        ):
            for pa, pb, real in tab.chunks(eng.count_chunk):
                with obs.span("engine.count.chunk", "dispatch"):
                    counts_sorted, skipped_tot = _count_chunk_program(
                        counts_sorted, skipped_tot,
                        tab.tiles, tab.tile_len, tab.tile_start,
                        pa, pb, real, eps,
                        dim_block=cfg.dim_block, shortc=shortc,
                        backend=backend,
                        interpret=eng.interpret,
                    )
                stats.num_chunks += 1
                stats.num_device_dispatches += 1
            counts = np.asarray(
                _unsort_counts(
                    counts_sorted, jnp.asarray(qplan.q_order, jnp.int32)
                )
            ).astype(np.int64)
        stats.num_results = int(counts.sum())
        stats.dim_blocks_skipped = int(skipped_tot)
        stats.dim_blocks_total = tab.num_pairs * snap.num_dim_blocks
        obs.mirror_selfjoin_stats(stats, path="engine", mode="count_query")
        return SelfJoinResult(counts=counts, stats=stats)

    def pairs(
        self,
        eps: Optional[float] = None,
        max_pairs: Optional[int] = None,
        _cap_hint: Optional[int] = None,
    ) -> SelfJoinResult:
        """Counts plus the materialized (a, b) pair list, original ids.

        With an explicit ``max_pairs`` (here or in ``EngineConfig``),
        overflow raises ``RuntimeError``.  Otherwise the buffer is sized
        from the paper's result-size estimate (Sec. 3.2.2); on overflow
        the exact |R| is known after the pass, so the buffer regrows to
        it in a single retry.  ``_cap_hint`` lets ``query()`` supply one
        shared auto-mode capacity for a whole eps sweep.
        """
        eps = self.config.eps if eps is None else float(eps)
        if self.num_points == 0:
            return SelfJoinResult(
                counts=np.zeros(0, np.int64),
                stats=self._base_stats(eps, self.snapshot),
                pairs=np.zeros((0, 2), np.int32),
            )
        self._ensure_index(eps)
        snap = self.snapshot
        cfg, eng = self.config, self.engine
        dec = self.resolve_execution(eps)
        tiles, tile_len, tile_start, chunks, plan, backend, _ = (
            self._self_tables(dec, snap)
        )

        explicit = max_pairs if max_pairs is not None else eng.max_pairs
        auto = explicit is None
        if not auto:
            cap = int(explicit)
        elif _cap_hint is not None:
            cap = int(_cap_hint)
        else:
            cap = self._auto_capacity(eps, dec)
        t = cfg.tile_size
        flat_per_chunk = eng.pairs_chunk * t * t
        hit_cap = min(flat_per_chunk, 4096)

        retries = 0
        dispatches = 0
        while True:
            stats = self._base_stats(eps, snap)
            self._record_decision(stats, dec)
            if dec.execution == "dense":
                stats.num_tile_pairs_evaluated = plan.num_pairs
                stats.num_candidates = plan.num_candidates
            buf = jnp.zeros((cap + hit_cap, 2), jnp.int32)
            offset = jnp.zeros((), jnp.int32)
            max_hits = jnp.zeros((), jnp.int32)
            with obs.span(
                "engine.pairs", "join",
                n=snap.num_points, eps=eps, tier=dec.execution,
                attempt=retries,
            ):
                for pa, pb, real in chunks(eng.pairs_chunk):
                    with obs.span("engine.pairs.chunk", "dispatch"):
                        buf, offset, max_hits = _pairs_chunk_program(
                            buf, offset, max_hits,
                            tiles, tile_len, tile_start,
                            snap.point_order, pa, pb, real, eps,
                            hit_cap=hit_cap, dim_block=cfg.dim_block,
                            backend=backend, interpret=eng.interpret,
                        )
                    stats.num_chunks += 1
                    dispatches += 1
                num = int(offset)
            # exact totals are known after a full pass, so each overflow kind
            # resolves in one retry: widen the per-chunk rank window first,
            # then (auto mode) regrow the buffer to the true |R|.
            if int(max_hits) > hit_cap and retries < _MAX_AUTO_GROW:
                obs.event(
                    "engine.pairs.retry", "retry", kind="hit_cap",
                    max_hits=int(max_hits), hit_cap=hit_cap,
                )
                hit_cap = min(flat_per_chunk, -(-int(max_hits) // 1024) * 1024)
                retries += 1
                continue
            if num > cap:
                if auto and eng.auto_grow and retries < _MAX_AUTO_GROW:
                    obs.event(
                        "engine.pairs.retry", "retry", kind="capacity",
                        num=num, cap=cap,
                    )
                    cap = batching_mod.suggest_pairs_capacity(num, 1.0)
                    retries += 1
                    continue
                raise RuntimeError(
                    f"result exceeded max_pairs={cap}; raise the cap or "
                    f"lower eps"
                )
            break

        pairs = np.asarray(buf[:num])
        counts = np.asarray(
            _counts_from_pairs(
                jnp.zeros(snap.num_points, jnp.int32), buf, offset
            )
        ).astype(np.int64)
        stats.num_results = int(counts.sum())
        stats.dim_blocks_total = plan.num_pairs * snap.num_dim_blocks
        stats.pairs_capacity = cap
        stats.overflow_retries = retries
        stats.num_device_dispatches = dispatches
        obs.mirror_selfjoin_stats(stats, path="engine", mode="pairs")
        return SelfJoinResult(counts=counts, stats=stats, pairs=pairs)

    def _auto_capacity(self, eps: float, dec: cost_mod.TierDecision) -> int:
        """Auto-mode pairs-buffer capacity from the paper's |R| estimate.

        The estimate samples the *chosen* tier's candidate pair list with
        the chosen backend, so the capacity reflects the tables that will
        actually run.
        """
        cfg, eng = self.config, self.engine
        tiles, tile_len, _, _, plan, backend, _ = self._self_tables(
            dec, self.snapshot
        )
        est = batching_mod.estimate_result_size(
            tiles, tile_len, plan, eps=eps,
            dim_block=cfg.dim_block, backend=backend,
            sample_frac=cfg.sample_frac, interpret=eng.interpret,
        )
        return batching_mod.suggest_pairs_capacity(est, eng.pairs_headroom)

    def query(
        self,
        eps_values: Sequence[float],
        return_pairs: bool = False,
        max_pairs: Optional[int] = None,
    ) -> List[SelfJoinResult]:
        """Multi-eps sweep over one snapshot and one set of executables.

        The snapshot is built once at ``max(eps_values)``; every eps then
        runs through the already-compiled chunk programs (eps is traced, so
        no recompilation happens between sweep points).  In auto-sized pairs
        mode the result-size estimate also runs once, at the largest eps --
        its capacity bounds every smaller sweep point.
        """
        eps_list = [float(e) for e in eps_values]
        if eps_list and self.num_points:
            self._ensure_index(max(eps_list))
        if return_pairs:
            cap_hint = None
            explicit = max_pairs if max_pairs is not None else self.engine.max_pairs
            if explicit is None and eps_list and self.num_points:
                dec = self.resolve_execution(max(eps_list))
                cap_hint = self._auto_capacity(max(eps_list), dec)
            return [
                self.pairs(e, max_pairs=max_pairs, _cap_hint=cap_hint)
                for e in eps_list
            ]
        return [self.count(e) for e in eps_list]
