# The paper's primary contribution: the TPU-native distance similarity
# self-join (GPU-Join of Gowanlock & Karsin 2018, adapted per DESIGN.md).
from repro.core.types import (  # noqa: F401
    EngineConfig,
    SelfJoinConfig,
    SelfJoinResult,
    SelfJoinStats,
)
from repro.core.selfjoin import self_join, self_join_hostloop  # noqa: F401
from repro.core.engine import SelfJoinEngine  # noqa: F401
from repro.core.snapshot import GridSnapshot, make_dense_plan  # noqa: F401
from repro.core.cost import (  # noqa: F401
    TierDecision,
    decide,
    dense_join_cost,
    indexed_join_cost,
)
from repro.core.dist_engine import DistributedSelfJoinEngine  # noqa: F401
from repro.core.reorder import variance_reorder, estimate_dim_variance  # noqa: F401
from repro.core.grid import build_grid, build_tile_plan, GridIndex, TilePlan  # noqa: F401
from repro.core.tuning import estimate_k_costs, select_k  # noqa: F401
from repro.core.partition import make_partition, assign_dynamic, simulate_scaling  # noqa: F401
