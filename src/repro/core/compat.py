"""JAX version-compatibility shims for the distributed tier.

The distributed ring join sits on two APIs whose spelling moved across JAX
releases:

``shard_map``
    new releases export it as ``jax.shard_map``; older ones (e.g. 0.4.x)
    only have ``jax.experimental.shard_map.shard_map``.

``pvary`` / ``pcast``
    newer shard_map enforces varying-manual-axes (vma) typing on loop
    carries, so a replicated zeros-carry must be explicitly cast to
    device-varying.  Releases that predate vma tracking have neither
    spelling -- and do not need the cast, so the correct fallback is a
    no-op, not an AttributeError.

Everything that touches the ring path (``core/distributed.py``,
``launch/selfjoin_dryrun.py``, ``benchmarks/bench_comm.py``) must import
these shims instead of reaching into ``jax`` directly.
"""
from __future__ import annotations

import jax


def resolve_shard_map():
    """Return the ``shard_map`` callable for this JAX version."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm_experimental

    return sm_experimental


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=True):
    """``jax.shard_map`` with the ``jax.experimental`` fallback applied.

    ``check_rep=False`` disables replication/varying-axes checking -- needed
    when the sharded body contains a ``pallas_call`` (no replication rule
    exists for it).  The flag's spelling moved across releases
    (``check_rep`` -> ``check_vma``), so both are tried; on versions with
    neither the plain call is returned (those predate the checker).
    """
    sm = resolve_shard_map()
    if not check_rep:
        for kw in ("check_vma", "check_rep"):
            try:
                return sm(
                    f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    **{kw: False},
                )
            except TypeError:
                continue
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def axis_size(axes):
    """Size of one or more shard_map axes, inside the sharded function.

    Uses ``jax.lax.axis_size`` where available and falls back to
    ``jax.lax.psum(1, axes)``, which constant-folds to a Python int for the
    unit input on every release old enough to lack ``axis_size``.
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    size_fn = getattr(jax.lax, "axis_size", None)
    if size_fn is not None:
        size = 1
        for a in axes_t:
            size *= size_fn(a)
        return size
    return int(jax.lax.psum(1, axes_t))


def ppermute(x, axes, perm):
    """``jax.lax.ppermute`` over one or more mesh axes.

    Normalizes the axis-name spelling (a single name for 1-axis rings, the
    tuple for joint rings such as ``("pod", "data")``) so callers can pass
    either form; ``perm`` is the usual source->destination pair list over
    the flattened ring positions.
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    name = axes_t if len(axes_t) > 1 else axes_t[0]
    return jax.lax.ppermute(x, name, perm)


class _NoopAnnotation:
    """Stand-in for ``jax.profiler.TraceAnnotation`` when unavailable."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NOOP_ANNOTATION = _NoopAnnotation()


def trace_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` context, or a no-op shim.

    Used by the ``repro.obs`` jax bridge so obs spans show up inside XLA
    profiler timelines on releases that have the profiler API, without
    making the tracer depend on it.
    """
    profiler = getattr(jax, "profiler", None)
    ta = getattr(profiler, "TraceAnnotation", None) if profiler is not None else None
    if ta is None:
        return _NOOP_ANNOTATION
    return ta(name)


def pvary(x, axes):
    """Cast ``x`` to device-varying over ``axes`` where the API exists.

    Tries the ``jax.lax.pcast(..., to="varying")`` spelling first, then
    ``jax.lax.pvary``; on versions with neither (no vma tracking in
    shard_map) the cast is unnecessary and ``x`` is returned unchanged.
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        return pcast(x, axes_t, to="varying")
    pvary_fn = getattr(jax.lax, "pvary", None)
    if pvary_fn is not None:
        return pvary_fn(x, axes_t)
    return x
