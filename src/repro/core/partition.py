"""Entity partitioning (paper Section 6.2).

Each processing element p_k (a GPU in the paper; a mesh slice here) gets a
round-robin selection of N_b query batches Q_l (l mod |p| == k), each of size
|D| / N_b, and joins Q_l against the full dataset.  Over-decomposition
(N_b >> |p|, N_b mod |p| == 0) is what gives the near-ideal balance of the
paper's Figs. 10-11 -- and doubles as straggler mitigation: a slow element
simply drains fewer batches when the host scheduler hands them out work-
stealing style (``assign_dynamic``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass
class EntityPartition:
    num_batches: int                 # N_b
    num_workers: int                 # |p|
    batch_bounds: np.ndarray         # (N_b + 1,) query-range boundaries
    assignment: np.ndarray           # (N_b,) worker of each batch (round robin)

    def batches_of(self, worker: int) -> List[int]:
        return [l for l in range(self.num_batches) if self.assignment[l] == worker]

    def query_range(self, batch: int):
        return int(self.batch_bounds[batch]), int(self.batch_bounds[batch + 1])


def make_partition(num_points: int, num_workers: int, num_batches: int) -> EntityPartition:
    """Round-robin entity partition; N_b is rounded up so N_b mod |p| == 0."""
    if num_workers < 1:
        raise ValueError("num_workers must be >= 1")
    n_b = max(num_batches, num_workers)
    if n_b % num_workers:
        n_b += num_workers - (n_b % num_workers)
    bounds = np.linspace(0, num_points, n_b + 1).round().astype(np.int64)
    assignment = np.arange(n_b, dtype=np.int64) % num_workers
    return EntityPartition(
        num_batches=n_b,
        num_workers=num_workers,
        batch_bounds=bounds,
        assignment=assignment,
    )


def assign_dynamic(batch_costs: Sequence[float], num_workers: int) -> np.ndarray:
    """Greedy longest-processing-time assignment (straggler mitigation).

    Used by the host scheduler when per-batch cost estimates exist (from the
    sampling pass); otherwise the paper's round-robin is already near-ideal
    because entity partitioning equalizes batch cost (Fig. 10).
    """
    costs = np.asarray(batch_costs, dtype=np.float64)
    order = np.argsort(-costs)
    load = np.zeros(num_workers)
    assignment = np.zeros(len(costs), dtype=np.int64)
    for b in order:
        w = int(np.argmin(load))
        assignment[b] = w
        load[w] += costs[b]
    return assignment


def simulate_scaling(
    batch_costs: Sequence[float],
    workers: Sequence[int],
    assignment: str = "round_robin",
):
    """Paper Fig. 11: simulated response time/speedup for |p| workers.

    ``assignment`` selects the paper's round-robin default or the greedy LPT
    scheduler (``"dynamic"``), so the straggler-mitigation benefit on skewed
    batch costs can be simulated directly.
    """
    costs = np.asarray(batch_costs, dtype=np.float64)
    out = []
    for p in workers:
        if assignment == "dynamic":
            assign = assign_dynamic(costs, p)
        else:
            assign = np.arange(len(costs)) % p
        t = max(costs[assign == w].sum() for w in range(p))
        out.append((p, t))
    t1 = out[0][1] if out else 1.0
    return [(p, t, t1 / t if t else float("inf")) for p, t in out]
