"""Typed counter / gauge / histogram registry with labels (DESIGN.md #11).

The registry is the cross-cutting view over the per-call stats objects
(`SelfJoinStats`, `ServiceStats`): those stay the public API and are
*mirrored* into the registry by the instrumentation layer while tracing is
enabled.  Metrics carry free-form string labels (tier, bucket, worker,
epoch, ...), support ``snapshot()``/``diff()`` for windowed accounting, and
export as JSON or Prometheus text exposition format.

Keys in a snapshot are ``(metric_name, ((label, value), ...))`` with labels
sorted, so two snapshots diff with plain dict arithmetic.
"""

from __future__ import annotations

import json
import threading
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramValue",
    "MetricsRegistry",
    "REGISTRY",
    "metric_value",
]

LabelKey = Tuple[Tuple[str, str], ...]
SnapKey = Tuple[str, LabelKey]

DEFAULT_BUCKETS = (
    1e-4,
    1e-3,
    1e-2,
    0.1,
    0.5,
    1.0,
    5.0,
    10.0,
    60.0,
    float("inf"),
)


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._values: Dict[LabelKey, object] = {}

    def labeled(self) -> List[Tuple[LabelKey, object]]:
        with self._lock:
            return list(self._values.items())


class Counter(_Metric):
    """Monotonically increasing count; ``inc`` with optional labels."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease (got {value})")
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._values.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """Point-in-time value; ``set``/``inc``/``dec`` with optional labels."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def dec(self, value: float = 1.0, **labels) -> None:
        self.inc(-value, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._values.get(_label_key(labels), 0.0))


class HistogramValue:
    """Immutable histogram reading: cumulative bucket counts + sum + count."""

    __slots__ = ("bounds", "bucket_counts", "sum", "count")

    def __init__(self, bounds, bucket_counts, sum_, count):
        self.bounds = tuple(bounds)
        self.bucket_counts = tuple(bucket_counts)
        self.sum = sum_
        self.count = count

    def __sub__(self, other: "HistogramValue") -> "HistogramValue":
        if self.bounds != other.bounds:
            raise ValueError("histogram bounds mismatch in diff")
        return HistogramValue(
            self.bounds,
            tuple(a - b for a, b in zip(self.bucket_counts, other.bucket_counts)),
            self.sum - other.sum,
            self.count - other.count,
        )

    def __eq__(self, other):
        return (
            isinstance(other, HistogramValue)
            and self.bounds == other.bounds
            and self.bucket_counts == other.bucket_counts
            and self.sum == other.sum
            and self.count == other.count
        )

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"HistogramValue(count={self.count}, sum={self.sum})"

    def to_json(self):
        return {
            "bounds": [b if b != float("inf") else "+Inf" for b in self.bounds],
            "bucket_counts": list(self.bucket_counts),
            "sum": self.sum,
            "count": self.count,
        }


class Histogram(_Metric):
    """Cumulative-bucket histogram; ``observe`` with optional labels."""

    kind = "histogram"

    def __init__(self, name, help, lock, buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, lock)
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds or bounds[-1] != float("inf"):
            bounds = bounds + (float("inf"),)
        self.bounds = bounds

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            cell = self._values.get(key)
            if cell is None:
                cell = self._values[key] = [[0] * len(self.bounds), 0.0, 0]
            counts, _, _ = cell
            for i, b in enumerate(self.bounds):
                if value <= b:
                    counts[i] += 1
            cell[1] += value
            cell[2] += 1

    def value(self, **labels) -> HistogramValue:
        with self._lock:
            cell = self._values.get(_label_key(labels))
            if cell is None:
                return HistogramValue(self.bounds, [0] * len(self.bounds), 0.0, 0)
            return HistogramValue(self.bounds, list(cell[0]), cell[1], cell[2])


class MetricsRegistry:
    """Get-or-create registry of typed metrics.

    Metric names are unique across kinds: asking for ``counter("x")`` after
    ``gauge("x")`` raises, which catches taxonomy drift early.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kwargs) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, self._lock, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, requested {cls.kind}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def clear(self) -> None:
        with self._lock:
            self._metrics = {}

    def snapshot(self) -> Dict[SnapKey, object]:
        """Flat copy: ``(name, labels)`` -> float or :class:`HistogramValue`."""
        out: Dict[SnapKey, object] = {}
        for m in self.metrics():
            for key, _ in m.labeled():
                out[(m.name, key)] = m.value(**dict(key))
        return out

    def diff(self, before: Dict[SnapKey, object]) -> Dict[SnapKey, object]:
        """Delta vs an earlier snapshot.

        Counters and histograms subtract; gauges report their current value
        (a gauge delta is rarely what a caller wants).  Keys absent from
        ``before`` diff against zero.
        """
        gauges = {m.name for m in self.metrics() if isinstance(m, Gauge)}
        out: Dict[SnapKey, object] = {}
        for key, after in self.snapshot().items():
            name, _ = key
            prior = before.get(key)
            if name in gauges or prior is None:
                out[key] = after
            else:
                out[key] = after - prior
        return out

    # -- exporters ---------------------------------------------------------

    def to_json(self) -> str:
        doc = []
        for m in self.metrics():
            series = []
            for key, _ in sorted(m.labeled()):
                v = m.value(**dict(key))
                series.append(
                    {
                        "labels": dict(key),
                        "value": v.to_json() if isinstance(v, HistogramValue) else v,
                    }
                )
            doc.append({"name": m.name, "kind": m.kind, "help": m.help, "series": series})
        return json.dumps(doc, indent=2, sort_keys=True)

    def to_prometheus_text(self) -> str:
        lines: List[str] = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key, _ in sorted(m.labeled()):
                v = m.value(**dict(key))
                if isinstance(v, HistogramValue):
                    for bound, c in zip(v.bounds, v.bucket_counts):
                        le = "+Inf" if bound == float("inf") else repr(bound)
                        lines.append(f"{m.name}_bucket{_prom_labels(key, le=le)} {c}")
                    lines.append(f"{m.name}_sum{_prom_labels(key)} {v.sum}")
                    lines.append(f"{m.name}_count{_prom_labels(key)} {v.count}")
                else:
                    lines.append(f"{m.name}{_prom_labels(key)} {_prom_num(v)}")
        return "\n".join(lines) + "\n"


def _prom_labels(key: LabelKey, **extra) -> str:
    items = list(key) + sorted(extra.items())
    if not items:
        return ""
    body = ",".join(f'{k}="{_prom_escape(v)}"' for k, v in items)
    return "{" + body + "}"


def _prom_escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _prom_num(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def metric_value(snap: Dict[SnapKey, object], name: str, **labels) -> float:
    """Sum a snapshot/diff's entries for ``name`` whose labels ⊇ ``labels``.

    Histograms contribute their ``count``.  Convenient for parity checks:
    ``metric_value(cap.metrics, "selfjoin_device_dispatches_total")``.
    """
    want = {k: str(v) for k, v in labels.items()}
    total = 0.0
    for (n, key), v in snap.items():
        if n != name:
            continue
        have = dict(key)
        if any(have.get(k) != wv for k, wv in want.items()):
            continue
        total += v.count if isinstance(v, HistogramValue) else v
    return total


REGISTRY = MetricsRegistry()
"""Process-wide default registry used by the mirror helpers."""
