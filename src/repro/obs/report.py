"""Per-phase / per-worker breakdown of a Chrome-trace dump.

``python -m repro.obs.report TRACE.json`` prints an aggregate table grouped
by span category and name, plus per-worker and per-round breakdowns when
the spans carry ``worker`` / ``round`` attributes (the ring tier does).
``--json`` emits the same report as JSON for machine consumption; a
malformed trace exits non-zero, which is what the CI gate relies on.

The loader accepts both the object form ``{"traceEvents": [...]}`` and the
bare-array form of the Chrome trace format, and validates each event enough
to catch truncated or hand-mangled dumps.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

__all__ = ["TraceFormatError", "load_trace", "build_report", "format_report", "main"]


class TraceFormatError(ValueError):
    """Raised when a trace file is not a well-formed Chrome trace."""


def load_trace(path: str) -> List[dict]:
    """Load + validate a Chrome-trace JSON file, returning its events."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise TraceFormatError(f"{path}: cannot parse trace: {e}") from e
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            raise TraceFormatError(f"{path}: missing 'traceEvents' array")
    elif isinstance(doc, list):
        events = doc
    else:
        raise TraceFormatError(f"{path}: top level must be an object or array")
    for i, e in enumerate(events):
        _validate_event(path, i, e)
    return events


def _validate_event(path: str, i: int, e: object) -> None:
    if not isinstance(e, dict):
        raise TraceFormatError(f"{path}: event {i} is not an object")
    ph = e.get("ph")
    if not isinstance(ph, str) or not ph:
        raise TraceFormatError(f"{path}: event {i} has no phase ('ph')")
    if ph == "M":
        return  # metadata events carry only name/args
    if not isinstance(e.get("name"), str):
        raise TraceFormatError(f"{path}: event {i} has no name")
    ts = e.get("ts")
    if not isinstance(ts, (int, float)):
        raise TraceFormatError(f"{path}: event {i} has non-numeric ts: {ts!r}")
    if ph == "X":
        dur = e.get("dur")
        if not isinstance(dur, (int, float)) or dur < 0:
            raise TraceFormatError(f"{path}: event {i} has bad dur: {dur!r}")
    args = e.get("args")
    if args is not None and not isinstance(args, dict):
        raise TraceFormatError(f"{path}: event {i} has non-object args")


class _Agg:
    __slots__ = ("count", "total_us", "max_us")

    def __init__(self):
        self.count = 0
        self.total_us = 0.0
        self.max_us = 0.0

    def add(self, dur_us: float) -> None:
        self.count += 1
        self.total_us += dur_us
        self.max_us = max(self.max_us, dur_us)

    def to_json(self) -> dict:
        return {
            "count": self.count,
            "total_us": round(self.total_us, 3),
            "mean_us": round(self.total_us / self.count, 3) if self.count else 0.0,
            "max_us": round(self.max_us, 3),
        }


def build_report(events: List[dict]) -> dict:
    """Aggregate events per (category, name), per worker, and per round."""
    phases: Dict[str, Dict[str, _Agg]] = {}
    workers: Dict[str, _Agg] = {}
    rounds: Dict[str, _Agg] = {}
    n_spans = n_instants = 0
    t_min = float("inf")
    t_max = float("-inf")
    for e in events:
        ph = e.get("ph")
        if ph not in ("X", "i"):
            continue
        dur = float(e.get("dur", 0.0))
        ts = float(e["ts"])
        t_min = min(t_min, ts)
        t_max = max(t_max, ts + dur)
        if ph == "X":
            n_spans += 1
        else:
            n_instants += 1
        cat = e.get("cat", "span")
        phases.setdefault(cat, {}).setdefault(e["name"], _Agg()).add(dur)
        args = e.get("args") or {}
        if "worker" in args:
            workers.setdefault(str(args["worker"]), _Agg()).add(dur)
        if "round" in args:
            rounds.setdefault(str(args["round"]), _Agg()).add(dur)
    return {
        "num_spans": n_spans,
        "num_instants": n_instants,
        "wall_us": round(t_max - t_min, 3) if n_spans + n_instants else 0.0,
        "phases": {
            cat: {name: agg.to_json() for name, agg in sorted(names.items())}
            for cat, names in sorted(phases.items())
        },
        "workers": {w: a.to_json() for w, a in sorted(workers.items())},
        "rounds": {r: a.to_json() for r, a in sorted(rounds.items())},
    }


def _table(rows: List[tuple], header: tuple) -> List[str]:
    widths = [max(len(str(r[i])) for r in [header] + rows) for i in range(len(header))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    out = [fmt.format(*header), fmt.format(*("-" * w for w in widths))]
    out.extend(fmt.format(*map(str, r)) for r in rows)
    return out


def format_report(rep: dict) -> str:
    lines = [
        f"events: {rep['num_spans']} spans + {rep['num_instants']} instants, "
        f"wall {rep['wall_us'] / 1e3:.3f} ms"
    ]
    rows = []
    for cat, names in rep["phases"].items():
        for name, a in names.items():
            rows.append(
                (cat, name, a["count"], f"{a['total_us'] / 1e3:.3f}",
                 f"{a['mean_us'] / 1e3:.3f}", f"{a['max_us'] / 1e3:.3f}")
            )
    rows.sort(key=lambda r: -float(r[3]))
    lines.append("")
    lines.extend(_table(rows, ("cat", "span", "count", "total_ms", "mean_ms", "max_ms")))
    for title, sec in (("worker", rep["workers"]), ("round", rep["rounds"])):
        if not sec:
            continue
        lines.append("")
        sub = [
            (k, a["count"], f"{a['total_us'] / 1e3:.3f}", f"{a['mean_us'] / 1e3:.3f}")
            for k, a in sec.items()
        ]
        lines.extend(_table(sub, (title, "count", "total_ms", "mean_ms")))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Per-phase/per-worker breakdown of a repro Chrome-trace dump.",
    )
    ap.add_argument("trace", help="Chrome-trace JSON file written by repro.obs")
    ap.add_argument("--json", action="store_true", help="emit the report as JSON")
    args = ap.parse_args(argv)
    try:
        events = load_trace(args.trace)
    except TraceFormatError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    rep = build_report(events)
    if args.json:
        json.dump(rep, sys.stdout, indent=2, sort_keys=True)
        print()
    else:
        print(format_report(rep))
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess in CI
    sys.exit(main())
