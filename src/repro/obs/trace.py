"""Zero-overhead-when-disabled span tracer (DESIGN.md #11).

Spans are context managers recording monotonic wall times (microseconds),
nesting depth, and typed attributes into a bounded ring buffer.  The module
is off by default: ``span()``/``event()`` check one module attribute and
return a shared no-op object / return immediately, so instrumented hot paths
cost a dict lookup and a branch when tracing is disabled.

When enabled, events accumulate in a ring buffer of fixed capacity; once
full the oldest events are overwritten and ``dropped_count()`` reports how
many were lost, so a runaway request stream can never exhaust host memory.

``to_chrome_trace()`` exports the buffer in Chrome-trace / Perfetto JSON
(``chrome://tracing``, https://ui.perfetto.dev).  ``enable(jax_bridge=True)``
additionally opens a ``jax.profiler.TraceAnnotation`` around every span (via
the ``repro.core.compat.trace_annotation`` shim, a no-op when the running
jax lacks the profiler API) so obs spans line up with XLA device traces.

This module deliberately imports nothing from ``repro.core`` at module
scope: the engine imports ``repro.obs``, and an eager core import here
would cycle through ``repro/core/__init__``.  The jax bridge is resolved
lazily inside :func:`enable`.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

DEFAULT_CAPACITY = 65536

__all__ = [
    "SpanEvent",
    "DEFAULT_CAPACITY",
    "enable",
    "disable",
    "enabled",
    "clear",
    "events",
    "event_count",
    "dropped_count",
    "span",
    "event",
    "to_chrome_trace",
    "write_chrome_trace",
]


class SpanEvent:
    """One recorded span ("X") or instant ("i") event, Chrome-trace shaped."""

    __slots__ = ("name", "cat", "ph", "ts_us", "dur_us", "tid", "depth", "attrs")

    def __init__(self, name, cat, ph, ts_us, dur_us, tid, depth, attrs):
        self.name = name
        self.cat = cat
        self.ph = ph
        self.ts_us = ts_us
        self.dur_us = dur_us
        self.tid = tid
        self.depth = depth
        self.attrs = attrs

    def __repr__(self):  # pragma: no cover - debugging aid
        return (
            f"SpanEvent({self.name!r}, cat={self.cat!r}, ph={self.ph!r}, "
            f"ts={self.ts_us:.1f}us, dur={self.dur_us:.1f}us, attrs={self.attrs!r})"
        )


class _State:
    __slots__ = ("enabled", "capacity", "buf", "next_i", "dropped", "t0", "bridge", "lock")

    def __init__(self):
        self.enabled = False
        self.capacity = DEFAULT_CAPACITY
        self.buf: List[SpanEvent] = []
        self.next_i = 0
        self.dropped = 0
        self.t0 = 0.0
        self.bridge: Optional[Callable[[str], Any]] = None
        self.lock = threading.Lock()


_state = _State()
_tls = threading.local()


def enabled() -> bool:
    """True when the tracer is currently recording."""
    return _state.enabled


def enable(capacity: int = DEFAULT_CAPACITY, *, jax_bridge: bool = False) -> None:
    """Start recording into a fresh ring buffer of ``capacity`` events.

    ``jax_bridge=True`` wraps every span in a ``jax.profiler``
    ``TraceAnnotation`` (no-op where unavailable) so obs spans appear in
    XLA profiler timelines too.
    """
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    bridge = None
    if jax_bridge:
        # Lazy: avoids repro.core <-> repro.obs import cycles and keeps the
        # default path jax-free.
        from repro.core.compat import trace_annotation

        bridge = trace_annotation
    with _state.lock:
        _state.capacity = int(capacity)
        _state.buf = []
        _state.next_i = 0
        _state.dropped = 0
        _state.t0 = time.perf_counter()
        _state.bridge = bridge
        _state.enabled = True


def disable() -> None:
    """Stop recording.  The buffer stays readable via :func:`events`."""
    _state.enabled = False


def clear() -> None:
    """Drop all recorded events (does not change enabled/disabled)."""
    with _state.lock:
        _state.buf = []
        _state.next_i = 0
        _state.dropped = 0


def events() -> List[SpanEvent]:
    """Recorded events, oldest first (post-overwrite order for full rings)."""
    with _state.lock:
        buf = _state.buf
        if len(buf) < _state.capacity or _state.next_i == 0:
            return list(buf)
        i = _state.next_i
        return buf[i:] + buf[:i]


def event_count() -> int:
    """Number of events currently held in the ring buffer."""
    return len(_state.buf)


def dropped_count() -> int:
    """Events overwritten because the ring buffer was full."""
    return _state.dropped


def _record(ev: SpanEvent) -> None:
    with _state.lock:
        buf = _state.buf
        if len(buf) < _state.capacity:
            buf.append(ev)
        else:
            buf[_state.next_i] = ev
            _state.next_i = (_state.next_i + 1) % _state.capacity
            _state.dropped += 1


def _depth_stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


class _NoopSpan:
    """Shared span stand-in returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs):
        return self


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "cat", "attrs", "_t0", "_depth", "_ann")

    def __init__(self, name: str, cat: str, attrs: Dict[str, Any]):
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self._t0 = 0.0
        self._depth = 0
        self._ann = None

    def set(self, **attrs):
        """Attach attributes discovered mid-span (e.g. sampled hit rates)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        stack = _depth_stack()
        self._depth = len(stack)
        stack.append(self.name)
        bridge = _state.bridge
        if bridge is not None:
            self._ann = bridge(self.name)
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        stack = _depth_stack()
        if stack:
            stack.pop()
        if _state.enabled:  # may have been disabled mid-span
            _record(
                SpanEvent(
                    self.name,
                    self.cat,
                    "X",
                    (self._t0 - _state.t0) * 1e6,
                    (t1 - self._t0) * 1e6,
                    threading.get_ident(),
                    self._depth,
                    self.attrs,
                )
            )
        return False


def span(name: str, cat: str = "span", **attrs):
    """Context manager recording a timed span.  One-branch no-op if disabled."""
    if not _state.enabled:
        return _NOOP
    return _Span(name, cat, attrs)


def event(name: str, cat: str = "event", **attrs) -> None:
    """Record an instant event (zero duration).  No-op if disabled."""
    if not _state.enabled:
        return
    _record(
        SpanEvent(
            name,
            cat,
            "i",
            (time.perf_counter() - _state.t0) * 1e6,
            0.0,
            threading.get_ident(),
            len(_depth_stack()),
            attrs,
        )
    )


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    # numpy scalars, jax scalars, enums, ... -- anything with item()/name
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:
            pass
    return str(v)


def to_chrome_trace(evts: Optional[List[SpanEvent]] = None, *, process_name: str = "repro") -> dict:
    """Export events as a Chrome-trace / Perfetto ``traceEvents`` dict."""
    if evts is None:
        evts = events()
    trace_events = [
        {
            "ph": "M",
            "pid": 0,
            "tid": 0,
            "name": "process_name",
            "args": {"name": process_name},
        }
    ]
    for e in evts:
        rec = {
            "name": e.name,
            "cat": e.cat,
            "ph": e.ph,
            "ts": round(e.ts_us, 3),
            "pid": 0,
            "tid": e.tid,
            "args": {k: _jsonable(v) for k, v in e.attrs.items()},
        }
        if e.ph == "X":
            rec["dur"] = round(e.dur_us, 3)
        else:
            rec["s"] = "t"  # instant scope: thread
        rec["args"]["depth"] = e.depth
        trace_events.append(rec)
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, evts: Optional[List[SpanEvent]] = None, *, process_name: str = "repro") -> str:
    """Write :func:`to_chrome_trace` JSON to ``path`` and return the path."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(evts, process_name=process_name), f)
    return path
