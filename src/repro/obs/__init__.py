"""Unified observability layer: span tracing + metrics registry (DESIGN.md #11).

Three pieces, one switch:

- :mod:`repro.obs.trace` — zero-overhead-when-disabled span tracer with a
  bounded ring buffer and a Chrome-trace/Perfetto exporter.
- :mod:`repro.obs.metrics` — typed counter/gauge/histogram registry with
  labels; the existing ``SelfJoinStats``/``ServiceStats`` counters are
  *mirrored* into it (they remain the per-call API).
- :mod:`repro.obs.report` — per-phase/per-worker breakdown CLI
  (``python -m repro.obs.report TRACE.json``).

Typical use::

    from repro import obs

    with obs.capture() as cap:
        engine.pairs()                  # or a stream of service requests
    cap.write_chrome_trace("trace.json")
    assert cap.span_count(cat="dispatch") == result.stats.num_device_dispatches
    obs.metric_value(cap.metrics, "selfjoin_device_dispatches_total")

Mirroring and recording only happen while tracing is enabled (normally via
``obs.capture()``), so production paths pay a single attribute check.
"""

from __future__ import annotations

import json as _json
import logging as _logging
from typing import Dict, List, Optional

from repro.obs import metrics as _metrics_mod
from repro.obs import trace as _trace_mod
from repro.obs.metrics import REGISTRY, MetricsRegistry, metric_value
from repro.obs.trace import (
    DEFAULT_CAPACITY,
    SpanEvent,
    clear,
    disable,
    dropped_count,
    enable,
    enabled,
    event,
    event_count,
    events,
    span,
    to_chrome_trace,
    write_chrome_trace,
)

__all__ = [
    "DEFAULT_CAPACITY",
    "SpanEvent",
    "MetricsRegistry",
    "REGISTRY",
    "metric_value",
    "enable",
    "disable",
    "enabled",
    "clear",
    "events",
    "event_count",
    "dropped_count",
    "span",
    "event",
    "to_chrome_trace",
    "write_chrome_trace",
    "inc",
    "observe",
    "set_gauge",
    "mirror_selfjoin_stats",
    "mirror_service_stats",
    "request_log",
    "Capture",
    "capture",
]

_LOG = _logging.getLogger("repro.obs")


# -- registry convenience (all gated on the tracer switch) -------------------

def inc(name: str, value: float = 1.0, **labels) -> None:
    """Increment a counter in the default registry (no-op when disabled)."""
    if _trace_mod._state.enabled:
        REGISTRY.counter(name).inc(value, **labels)


def observe(name: str, value: float, **labels) -> None:
    """Record a histogram observation (no-op when disabled)."""
    if _trace_mod._state.enabled:
        REGISTRY.histogram(name).observe(value, **labels)


def set_gauge(name: str, value: float, **labels) -> None:
    """Set a gauge (no-op when disabled)."""
    if _trace_mod._state.enabled:
        REGISTRY.gauge(name).set(value, **labels)


def mirror_selfjoin_stats(stats, *, path: str, mode: str) -> None:
    """Mirror a completed join's ``SelfJoinStats`` into the registry.

    ``path`` names the execution path ("engine", "ring_host", "ring_fused"),
    ``mode`` the result shape ("count", "pairs").  The tier label is the
    tier that actually ran.  Counts mirror 1:1 — a parity test can compare
    ``selfjoin_device_dispatches_total`` against the stats object directly.
    """
    if not _trace_mod._state.enabled:
        return
    tier = stats.execution or "indexed"
    labels = dict(path=path, mode=mode, tier=tier)
    c = REGISTRY.counter
    c("selfjoin_joins_total", "completed self-join calls").inc(1, **labels)
    c("selfjoin_device_dispatches_total", "host->device program launches").inc(
        stats.num_device_dispatches, **labels
    )
    c("selfjoin_chunks_total", "chunk programs in the final attempt").inc(
        stats.num_chunks, **labels
    )
    c("selfjoin_candidates_total", "point comparisons evaluated").inc(
        stats.num_candidates, **labels
    )
    c("selfjoin_results_total", "result rows (|R|)").inc(stats.num_results, **labels)
    c("selfjoin_overflow_retries_total", "pairs-buffer regrow retries").inc(
        stats.overflow_retries, **labels
    )


def mirror_service_stats(stats, *, kind: str) -> None:
    """Mirror one request's ``ServiceStats`` into the registry.

    ``kind`` is the request type ("range_count", "range_pairs", "knn").
    Gauges track the churn state the request observed (epoch, delta size,
    tombstones); counters mirror the per-request work counters.
    """
    if not _trace_mod._state.enabled:
        return
    tier = stats.execution or "indexed"
    labels = dict(kind=kind, tier=tier)
    c = REGISTRY.counter
    c("service_requests_total", "requests served").inc(stats.num_requests, **labels)
    c("service_queries_total", "query rows served").inc(stats.num_queries, **labels)
    c("service_traces_total", "new chunk-program traces caused").inc(
        stats.num_traces, **labels
    )
    c("service_dispatches_total", "chunk-program launches").inc(
        stats.num_device_dispatches, **labels
    )
    c("service_results_total", "neighbours counted / pairs returned").inc(
        stats.num_results, **labels
    )
    c("service_eps_rounds_total", "eps-expansion passes").inc(
        stats.eps_rounds, **labels
    )
    c("service_index_rebuilds_total", "over-radius temporary snapshots").inc(
        stats.index_rebuilds, **labels
    )
    g = REGISTRY.gauge
    g("service_epoch", "compaction epoch last pinned").set(stats.epoch)
    g("service_delta_size", "delta-buffer points at last request").set(stats.delta_size)
    g("service_tombstones", "tombstoned points at last request").set(
        stats.tombstone_count
    )
    REGISTRY.histogram("service_request_queries", "query rows per request").observe(
        stats.num_queries, kind=kind
    )


def request_log(kind: str, stats) -> None:
    """Per-request structured log record: instant trace event + debug log."""
    fields = {
        "kind": kind,
        "nq": stats.num_queries,
        "bucket": stats.bucket,
        "eps": round(float(stats.eps), 6),
        "eps_rounds": stats.eps_rounds,
        "traces": stats.num_traces,
        "dispatches": stats.num_device_dispatches,
        "results": stats.num_results,
        "tier": stats.execution,
        "epoch": stats.epoch,
    }
    if _trace_mod._state.enabled:
        event("service.request", "log", **fields)
    if _LOG.isEnabledFor(_logging.DEBUG):
        _LOG.debug("request %s", _json.dumps(fields, sort_keys=True))


# -- capture -----------------------------------------------------------------

class Capture:
    """Result of an ``obs.capture()`` window.

    ``events`` is the recorded span/event list, ``metrics`` the registry
    delta over the window (see :meth:`MetricsRegistry.diff`), ``dropped``
    how many events the ring buffer overwrote.
    """

    def __init__(self):
        self.events: List[SpanEvent] = []
        self.metrics: Dict = {}
        self.dropped: int = 0

    def spans(self, name: Optional[str] = None, cat: Optional[str] = None) -> List[SpanEvent]:
        return [
            e
            for e in self.events
            if (name is None or e.name == name) and (cat is None or e.cat == cat)
        ]

    def span_count(self, name: Optional[str] = None, cat: Optional[str] = None) -> int:
        return len(self.spans(name, cat))

    def metric(self, name: str, **labels) -> float:
        """Summed registry delta for ``name`` (labels filter as a subset)."""
        return metric_value(self.metrics, name, **labels)

    def chrome_trace(self) -> dict:
        return to_chrome_trace(self.events)

    def write_chrome_trace(self, path: str) -> str:
        return write_chrome_trace(path, self.events)


class capture:
    """Context manager: record spans + a registry delta over a window.

    Enables the tracer on entry (fresh ring buffer) and restores the
    previous tracer state on exit, so captures can wrap production code
    that is otherwise uninstrumented-at-rest.  Captures do not share their
    buffer with an enclosing ``enable()`` window — events recorded inside
    the capture belong to the capture.
    """

    def __init__(
        self,
        capacity: int = DEFAULT_CAPACITY,
        *,
        registry: Optional[MetricsRegistry] = None,
        jax_bridge: bool = False,
    ):
        self._capacity = capacity
        self._registry = registry if registry is not None else REGISTRY
        self._jax_bridge = jax_bridge
        self._cap: Optional[Capture] = None
        self._before: Optional[Dict] = None
        self._prev_enabled = False

    def __enter__(self) -> Capture:
        self._prev_enabled = enabled()
        enable(self._capacity, jax_bridge=self._jax_bridge)
        self._before = self._registry.snapshot()
        self._cap = Capture()
        return self._cap

    def __exit__(self, exc_type, exc, tb):
        cap = self._cap
        cap.events = events()
        cap.dropped = dropped_count()
        cap.metrics = self._registry.diff(self._before)
        disable()
        clear()
        if self._prev_enabled:
            # Re-open recording for the enclosing window (fresh buffer; the
            # enclosing window's earlier events were its own snapshot).
            enable(self._capacity, jax_bridge=self._jax_bridge)
        return False
