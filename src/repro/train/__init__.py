from repro.train.optimizer import adamw_init, adamw_update, OptHParams  # noqa: F401
from repro.train.steps import make_train_step, make_serve_step, make_prefill  # noqa: F401
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, latest_step  # noqa: F401
