"""Jittable train / prefill / serve steps (what the dry-run lowers).

``make_train_step``: fwd + CE loss + bwd + clipped AdamW, donating params
and optimizer state.  ``make_serve_step``: one decode token against the
caches.  Gradient all-reduce runs in bf16 when the config's activation
dtype is bf16 (gradient compression, DESIGN.md #4) -- the optimizer math
upcasts to fp32 per update.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward_loss
from repro.train.optimizer import OptHParams, adamw_update


def make_train_step(cfg, hp: OptHParams):
    def loss_fn(params, batch):
        return forward_loss(params, batch, cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if cfg.activation_dtype == "bfloat16":
            # bf16 gradient all-reduce (compression); fp32 again in AdamW
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, hp)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill(cfg, cache_len: int):
    from repro.models import prefill

    def prefill_step(params, batch):
        logits, caches, memory = prefill(params, batch, cfg, cache_len)
        return logits, caches, memory

    return prefill_step


def make_serve_step(cfg, *, greedy: bool = True):
    def serve_step(params, caches, token, pos, memory=None):
        logits, caches = decode_step(params, caches, token, pos, cfg, memory=memory)
        logits = logits[..., : cfg.vocab]
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, caches

    return serve_step
