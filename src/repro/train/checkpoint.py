"""Sharded checkpoint save/restore (fault tolerance + elastic re-mesh).

Layout:  <dir>/step_<N>/
            manifest.json       -- step, leaf paths, shapes, dtypes
            <leaf-hash>.npy     -- one file per pytree leaf (global array)

Writes go to a temp directory that is atomically renamed, so a node failure
mid-save never corrupts the latest checkpoint; restore picks the newest
complete manifest.  Arrays are stored with their GLOBAL shape and re-sharded
on load against whatever mesh the restart runs on -- restarting 512-chip
training on 256 chips (elastic downscale) only changes the NamedSharding
passed to ``restore_checkpoint``.  In a true multi-host deployment each host
writes only its addressable shards; on this single-process runtime that
degenerates to full arrays, but the manifest format is host-count agnostic.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_name(path) -> str:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    name = "/".join(keys)
    return name


def _fname(name: str) -> str:
    return hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None):
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        fn = _fname(name)
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str, like: Any, step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays/structs).

    ``shardings``: optional matching pytree of NamedSharding -- arrays are
    device_put against it (elastic re-mesh happens here).
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (
        jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (path, leaf), sh in zip(flat, shard_flat):
        name = _leaf_name(path)
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(d, meta["file"]))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != expected {leaf.shape}")
        leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
    return tree, step, manifest.get("extra", {})
