"""AdamW with global-norm clipping and configurable state dtype.

State dtype bf16 for the >=236B configs (arctic, deepseek) so optimizer
state fits HBM at pod scale (DESIGN.md #4); the update math always runs in
fp32 (m/v are upcast per step), so bf16 state costs precision only in the
rounding of the stored moments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptHParams:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def schedule(hp: OptHParams, step):
    """Linear warmup + cosine decay to 10%."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(hp.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (s - hp.warmup_steps) / jnp.maximum(hp.total_steps - hp.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * frac)
    return hp.lr * warm * cos


def adamw_init(params, state_dtype: str = "float32"):
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params, grads, state, hp: OptHParams
) -> Tuple[Any, Any, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    lr = schedule(hp, step)
    b1c = 1.0 - hp.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - hp.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = hp.b1 * m.astype(jnp.float32) + (1 - hp.b1) * g32
        v32 = hp.b2 * v.astype(jnp.float32) + (1 - hp.b2) * g32 * g32
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + hp.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + hp.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, new_state, metrics
