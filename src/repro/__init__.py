"""repro: TPU-native similarity self-join framework.

Reproduction of Gowanlock & Karsin 2018 ("GPU Accelerated Similarity
Self-Join for Multi-Dimensional Data") as a production JAX framework --
see DESIGN.md for the paper->system map and EXPERIMENTS.md for the
dry-run/roofline/perf results.
"""

__version__ = "1.0.0"
