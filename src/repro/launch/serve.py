"""Batched serving driver: prefill a batch of prompts, then decode greedily.

    python -m repro.launch.serve --arch gemma3_12b --batch 4 --prompt-len 32 \
        --max-new 16

Uses the same prefill/decode_step the dry-run lowers for the
prefill_32k/decode_32k cells, at reduced config on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models import init_params, prefill
from repro.train import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm_125m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else get_reduced_config(args.arch)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    cache_len = args.prompt_len + args.max_new

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.encoder_groups is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, 16, cfg.enc_input_dim)), jnp.float32)
    if cfg.vision_tokens:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.vision_tokens, cfg.vision_dim)),
            jnp.float32)

    t0 = time.time()
    logits, caches, memory = prefill(params, batch, cfg, cache_len=cache_len)
    tok = jnp.argmax(logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    serve = jax.jit(
        (lambda p, c, t, pos, mem: make_serve_step(cfg)(p, c, t, pos, memory=mem))
        if memory is not None else
        (lambda p, c, t, pos: make_serve_step(cfg)(p, c, t, pos))
    )
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.max_new - 1):
        pos = jnp.int32(args.prompt_len + i)
        serve_args = (params, caches, tok, pos) + ((memory,) if memory is not None else ())
        tok, _, caches = serve(*serve_args)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"prefill: {t_prefill*1e3:.0f} ms   decode: "
          f"{t_decode/max(args.max_new-1,1)*1e3:.1f} ms/token")
    for b in range(min(args.batch, 2)):
        print(f"  sample[{b}]: {gen[b].tolist()}")
    return gen


if __name__ == "__main__":
    main()
