import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run + roofline for the PAPER'S technique at pod scale: the entity-
partitioned ring self-join (Sec. 6.3) on the production meshes.

Workload: |D| points x n dims sharded over all chips (joint ring over
("pod","data","model") -- every chip is a ring node, as every GPU is a node
in the paper).  Variants are the hillclimb levers recorded in EXPERIMENTS.md
#Perf (cell C):

  base        fp32 coordinates, compute-then-permute
  overlap     permute issued before compute (round i+1 transport overlaps
              round i compute -- paper Fig. 4's pipeline, at ring scale)
  bf16        bf16 coordinate transport/compute, fp32 accumulation
              (documented approximate variant: ~3 decimal digits)

Usage: python -m repro.launch.selfjoin_dryrun [--points 16777216] [--dims 32]
"""
import argparse     # noqa: E402
import json         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import compat  # noqa: E402
from repro.core.distributed import ring_scan  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_desc  # noqa: E402
from repro.roofline import roofline_terms  # noqa: E402


def ring_fn(mesh, axes, eps, *, variant="base", row_block=2048):
    eps2 = float(eps) ** 2
    axes_t = tuple(axes)

    def local_counts(q, e):
        qc = q
        if variant == "bf16":
            qc, e = q.astype(jnp.bfloat16), e.astype(jnp.bfloat16)
        ne = jnp.einsum("ij,ij->i", e, e, preferred_element_type=jnp.float32)
        blocks = qc.reshape(-1, row_block, q.shape[1])

        def one(qb):
            d2 = (
                jnp.einsum("ij,ij->i", qb, qb, preferred_element_type=jnp.float32)[:, None]
                + ne[None, :]
                - 2.0 * jnp.einsum("id,jd->ij", qb, e, preferred_element_type=jnp.float32)
            )
            return jnp.sum(d2 <= eps2, axis=1, dtype=jnp.int32)

        return jax.lax.map(one, blocks).reshape(-1)

    def body_fn(d_block):
        q = d_block

        def body(_, counts, e):
            return counts + local_counts(q, e)

        counts0 = compat.pvary(jnp.zeros(q.shape[0], jnp.int32), axes_t)
        # overlap variant: ring_scan issues round r+1's permute before round
        # r's body -- paper Fig. 4's pipeline, at ring scale
        return ring_scan(
            axes_t, body, counts0, q, overlap=(variant == "overlap")
        )

    spec = P(axes_t if len(axes_t) > 1 else axes_t[0])
    return jax.jit(compat.shard_map(body_fn, mesh=mesh, in_specs=spec, out_specs=spec))


def run_cell(points, dims, eps, multi_pod, variant, row_block=2048):
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names
    chips = 1
    for a in axes:
        chips *= mesh.shape[a]
    fn = ring_fn(mesh, axes, eps, variant=variant, row_block=row_block)
    d_abs = jax.ShapeDtypeStruct((points, dims), jnp.float32)
    with mesh:
        lowered = fn.lower(d_abs)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    # model flops: |D|^2 pair distances x 3n flops (paper Sec. 4.4), one pass
    model_flops = 3.0 * dims * float(points) ** 2
    rep = roofline_terms(
        arch=f"selfjoin-ring-{variant}", shape=f"D{points}xn{dims}",
        mesh_desc=mesh_desc(mesh), chips=chips,
        hlo_text=compiled.as_text(), model_flops=model_flops,
        memory_analysis=mem,
    )
    d = rep.as_dict()
    d["temp_bytes_per_chip"] = mem.temp_size_in_bytes
    d["arg_bytes_per_chip"] = mem.argument_size_in_bytes
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=16_777_216)  # 2^24, ~2GB fp32 @32d
    ap.add_argument("--dims", type=int, default=32)
    ap.add_argument("--eps", type=float, default=0.08)
    ap.add_argument("--out", default="experiments/selfjoin_ring.json")
    args = ap.parse_args()

    out = {}
    for multi_pod in (False, True):
        for variant in ("base", "overlap", "bf16"):
            tag = f"{'pod2' if multi_pod else 'pod1'}__{variant}"
            d = run_cell(args.points, args.dims, args.eps, multi_pod, variant)
            out[tag] = d
            print(
                f"{tag:16s} comp={d['compute_s']:.3f}s mem={d['memory_s']:.3f}s "
                f"coll={d['collective_s']:.3f}s dom={d['dominant']} "
                f"frac={d['roofline_fraction']:.3f} mfu={d['mfu']:.3f} "
                f"temp={d['temp_bytes_per_chip']/1e9:.2f}GB", flush=True,
            )
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
