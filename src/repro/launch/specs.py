"""Input ShapeDtypeStruct stand-ins per (architecture x shape) cell.

The assigned LM shape set:
    train_4k      seq 4096,    global_batch 256   (train_step)
    prefill_32k   seq 32768,   global_batch 32    (prefill)
    decode_32k    context 32k, global_batch 128   (serve_step)
    long_500k     context 512k, global_batch 1    (serve_step, sub-quadratic
                                                   archs only)

Modality frontends are stubs (assignment): audio frames / vision patches are
precomputed embeddings in the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": dict(kind="train", seq=4_096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1),
}

AUDIO_FRAMES = 1024  # stub speech-encoder output length (seamless)


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str                     # train | prefill | decode
    batch: Dict[str, Any]         # ShapeDtypeStructs
    seq: int
    global_batch: int
    skip_reason: Optional[str] = None


def applicable(cfg, shape_name: str) -> Optional[str]:
    """None if the cell runs; else the skip reason (recorded in DESIGN.md)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return (
            "full-attention arch: 524k dense decode is quadratic full "
            "attention; skipped per assignment (DESIGN.md #3)"
        )
    return None


def input_specs(cfg, shape_name: str) -> CellSpec:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    info = SHAPES[shape_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    i32 = jnp.int32
    b: Dict[str, Any] = {}
    if kind == "train":
        b["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        b["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
    elif kind == "prefill":
        b["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        b["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)  # unused by prefill
    else:  # decode
        b["token"] = jax.ShapeDtypeStruct((batch,), i32)
    if cfg.encoder_groups is not None and kind != "decode":
        b["frames"] = jax.ShapeDtypeStruct(
            (batch, AUDIO_FRAMES, cfg.enc_input_dim), jnp.float32
        )
    if cfg.vision_tokens and kind != "decode":
        b["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.vision_dim), jnp.float32
        )
    return CellSpec(
        arch=cfg.name, shape=shape_name, kind=kind, batch=b, seq=seq,
        global_batch=batch, skip_reason=applicable(cfg, shape_name),
    )


def memory_spec(cfg, batch: int):
    """Decode-time cross-attention memory (enc-dec / VLM), already projected."""
    if cfg.encoder_groups is not None:
        return jax.ShapeDtypeStruct((batch, AUDIO_FRAMES, cfg.d_model), jnp.dtype(cfg.activation_dtype))
    if cfg.vision_tokens:
        return jax.ShapeDtypeStruct((batch, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.activation_dtype))
    return None
