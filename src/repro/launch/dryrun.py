import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

MUST be the very first lines above: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.  Do NOT
set this flag anywhere else (smoke tests and benches see 1 device).

For each cell this script:
  1. builds abstract params/optimizer/caches (jax.eval_shape -- no memory),
  2. jits the train/prefill/serve step with explicit in/out shardings,
  3. ``.lower().compile()`` against the production mesh,
  4. prints ``memory_analysis()`` (proves it fits) and ``cost_analysis()``,
  5. parses the per-partition HLO for trip-count-aware FLOPs / HBM bytes /
     collective wire bytes and writes experiments/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse   # noqa: E402
import functools  # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax                                   # noqa: E402
import jax.numpy as jnp                      # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, canonical, get_config   # noqa: E402
from repro.launch import specs as S          # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_desc  # noqa: E402
from repro.models import abstract_params, init_caches, prefill  # noqa: E402
from repro.roofline import roofline_terms    # noqa: E402
from repro.roofline.analysis import (        # noqa: E402
    model_flops_decode, model_flops_prefill, model_flops_train,
)
from repro.sharding import batch_spec, cache_specs, dp_axes, param_specs  # noqa: E402
from repro.train import OptHParams, adamw_init, make_serve_step, make_train_step  # noqa: E402

FSDP_ARCHS = {"arctic_480b", "deepseek_v2_236b"}
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _opt_specs(pspecs):
    return {
        "m": pspecs, "v": pspecs, "step": P(),
    }


def lower_cell(arch: str, shape: str, multi_pod: bool, sharding_overrides=None):
    """Lower + compile one cell; returns (report dict, compiled)."""
    cfg = get_config(arch)
    cell = S.input_specs(cfg, shape)
    if cell.skip_reason:
        return {"arch": arch, "shape": shape, "skipped": cell.skip_reason}, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]
    fsdp = canonical(arch) in FSDP_ARCHS

    params_abs = abstract_params(cfg)
    pspecs = param_specs(params_abs, mesh, fsdp=fsdp)
    if sharding_overrides:
        pspecs = sharding_overrides(pspecs, mesh, cfg)
    params_sh = _ns(mesh, pspecs)

    t0 = time.time()
    if cell.kind == "train":
        opt_abs = jax.eval_shape(
            functools.partial(adamw_init, state_dtype=cfg.opt_state_dtype), params_abs
        )
        opt_sh = _ns(mesh, _opt_specs(pspecs))
        batch_sh = _ns(mesh, batch_spec(cell.batch, mesh))
        step_fn = make_train_step(cfg, OptHParams())
        jitted = jax.jit(
            step_fn,
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, cell.batch)
        model_flops = model_flops_train(cfg, cell.global_batch, cell.seq)
    elif cell.kind == "prefill":
        batch_sh = _ns(mesh, batch_spec(cell.batch, mesh))

        def prefill_fn(params, batch):
            return prefill(params, batch, cfg, cache_len=cell.seq)

        caches_abs = jax.eval_shape(
            lambda: init_caches(cfg, cell.global_batch, cell.seq)
        )
        cspecs = cache_specs(caches_abs, mesh)
        out_sh = (None, _ns(mesh, cspecs), None)
        jitted = jax.jit(prefill_fn, in_shardings=(params_sh, batch_sh),
                         out_shardings=out_sh)
        with mesh:
            lowered = jitted.lower(params_abs, cell.batch)
        model_flops = model_flops_prefill(cfg, cell.global_batch, cell.seq)
    else:  # decode
        caches_abs = jax.eval_shape(
            lambda: init_caches(cfg, cell.global_batch, cell.seq)
        )
        cspecs = cache_specs(caches_abs, mesh)
        caches_sh = _ns(mesh, cspecs)
        dp = dp_axes(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        tok_sh = NamedSharding(
            mesh, P(dp) if cell.global_batch % dp_size == 0 else P(None)
        )
        mem_abs = S.memory_spec(cfg, cell.global_batch)
        serve = make_serve_step(cfg)

        step_fn = (
            (lambda p, c, t, pos, mem: serve(p, c, t, pos, memory=mem))
            if mem_abs is not None else
            (lambda p, c, t, pos: serve(p, c, t, pos))
        )
        in_sh = [params_sh, caches_sh, tok_sh, None]
        args = [params_abs, caches_abs,
                jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32)]
        if mem_abs is not None:
            in_sh.append(NamedSharding(mesh, P(None, None, None)))
            args.append(mem_abs)
        jitted = jax.jit(
            step_fn, in_shardings=tuple(in_sh),
            out_shardings=(tok_sh, None, caches_sh),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = jitted.lower(*args)
        model_flops = model_flops_decode(cfg, cell.global_batch, cell.seq)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch} x {shape} x {mesh_desc(mesh)}] memory_analysis:", mem)
    try:
        ca = compiled.cost_analysis()
    except Exception:
        ca = {}
    # older jaxlib CPU backends return one dict per partition instead of a
    # single dict -- normalize before any .get()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        ca = {}
    print(f"[{arch} x {shape}] cost_analysis flops={ca.get('flops')} bytes={ca.get('bytes accessed')}")

    report = roofline_terms(
        arch=arch, shape=shape, mesh_desc=mesh_desc(mesh), chips=chips,
        hlo_text=compiled.as_text(), model_flops=model_flops,
        cost_analysis=ca, memory_analysis=mem,
    )
    d = report.as_dict()
    d.update(
        lower_s=t_lower, compile_s=t_compile, kind=cell.kind,
        seq=cell.seq, global_batch=cell.global_batch, fsdp=fsdp,
        temp_bytes_per_chip=getattr(mem, "temp_size_in_bytes", None),
        arg_bytes_per_chip=getattr(mem, "argument_size_in_bytes", None),
        output_bytes_per_chip=getattr(mem, "output_size_in_bytes", None),
        param_count=cfg.param_count(),
        active_param_count=cfg.active_param_count(),
    )
    return d, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(S.SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{canonical(arch)}__{shape}__{'pod2' if mp else 'pod1'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_done and os.path.exists(path):
                    print("skip (done):", tag)
                    continue
                print("=== cell:", tag, flush=True)
                try:
                    d, _ = lower_cell(arch, shape, mp)
                    with open(path, "w") as f:
                        json.dump(d, f, indent=1)
                    if "skipped" in d:
                        print("SKIPPED:", d["skipped"])
                    else:
                        print(
                            f"ok t_lower={d['lower_s']:.1f}s t_compile={d['compile_s']:.1f}s "
                            f"dominant={d['dominant']} step={d['step_time_s']*1e3:.2f}ms "
                            f"frac={d['roofline_fraction']:.3f} mfu={d['mfu']:.3f}",
                            flush=True,
                        )
                except Exception as e:  # record the failure, keep sweeping
                    failures.append(tag)
                    with open(path + ".fail", "w") as f:
                        f.write(traceback.format_exc())
                    print("FAIL:", tag, type(e).__name__, str(e)[:200], flush=True)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("all requested cells compiled.")


if __name__ == "__main__":
    main()
