"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int = 8):
    """Small mesh for CPU multi-device tests (2 x devices/2)."""
    return jax.make_mesh((2, devices // 2), ("data", "model"))


def mesh_desc(mesh) -> str:
    return "x".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
