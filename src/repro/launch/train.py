"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Fault-tolerant loop: deterministic data cursor, periodic sharded checkpoints
(atomic commit), automatic resume from the latest complete checkpoint --
kill the process at any step and rerun the same command to continue.  On a
real cluster each host runs this same binary under `jax.distributed`
(launcher note in README); on CPU it trains the reduced config.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.data.tokens import TokenPipeline
from repro.data.dedup import dedup_token_dataset
from repro.models import init_params
from repro.train import (
    OptHParams, adamw_init, make_train_step,
    restore_checkpoint, save_checkpoint, latest_step,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm_125m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published config (needs a real pod)")
    ap.add_argument("--dedup", action="store_true",
                    help="run the self-join near-dup filter on the warmup batch "
                         "(the paper's technique in the input pipeline)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else get_reduced_config(args.arch)
    hp = OptHParams(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)

    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params, cfg.opt_state_dtype)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        like = jax.eval_shape(lambda: {"params": params, "opt": opt})
        tree, step, extra = restore_checkpoint(args.ckpt_dir, like)
        params, opt = tree["params"], tree["opt"]
        start = int(extra.get("data_cursor", step))
        print(f"resumed from step {step} (data cursor {start})")

    if args.dedup:
        warm = pipe.batch_at(start)["tokens"]
        kept = dedup_token_dataset(warm, eps=0.05)
        print(f"dedup: kept {kept.shape[0]}/{warm.shape[0]} examples")

    step_fn = jax.jit(make_train_step(cfg, hp), donate_argnums=(0, 1))
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in pipe.batch_at(step).items()}
        if cfg.encoder_groups is not None:
            rng = np.random.default_rng(step)
            batch["frames"] = jax.numpy.asarray(
                rng.normal(size=(args.batch, 16, cfg.enc_input_dim)).astype(np.float32))
        if cfg.vision_tokens:
            rng = np.random.default_rng(step)
            batch["patches"] = jax.numpy.asarray(
                rng.normal(size=(args.batch, cfg.vision_tokens, cfg.vision_dim)).astype(np.float32))
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt},
                            extra={"data_cursor": step + 1})
    print("done.")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
