# The online similarity query service (DESIGN.md #8, #10): a persistent
# device-resident MUTABLE index (build once, save/load across restarts,
# insert/delete/compact between requests) serving batched epsilon range
# queries and kNN on top of the paper's grid join.
from repro.join.index import (  # noqa: F401
    IndexView,
    PendingCompact,
    SimilarityIndex,
)
from repro.join.service import (  # noqa: F401
    KnnResult,
    QueryService,
    RangeCountResult,
    RangePairsResult,
    ServiceStats,
)
