# The online similarity query service (DESIGN.md #8): a persistent
# device-resident index (build once, save/load across restarts) serving
# batched epsilon range queries and kNN on top of the paper's grid join.
from repro.join.index import SimilarityIndex  # noqa: F401
from repro.join.service import (  # noqa: F401
    KnnResult,
    QueryService,
    RangeCountResult,
    RangePairsResult,
    ServiceStats,
)
