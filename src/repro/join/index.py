"""Persistent, *mutable* device-resident similarity index (DESIGN.md #8, #10).

``SimilarityIndex`` owns the serving tier's data plane: a ``SelfJoinEngine``
whose frozen ``GridSnapshot`` answers the bulk of every query, plus the
mutable churn state that lets the dataset change without a rebuild:

  inserts    -- ``insert(points)`` appends to a delta buffer (host log +
                lazily device-placed pow2-padded array) that the service
                brute/dense-joins against every query batch;
  deletes    -- ``delete(ids)`` tombstones snapshot points (delta points
                are simply dropped from the buffer); tombstoned rows are
                masked out of counts/pairs/kNN at the query epilogue;
  compaction -- ``compact()`` rebuilds a fresh snapshot over the live set
                (base points minus tombstones plus delta, ascending global
                id) and atomically swaps it in via
                ``SelfJoinEngine.swap_snapshot``; the build phase is pure
                (``prepare_compact``) so it can run off the serving path,
                and the swap is one reference assignment.

Every point carries a **global id**, stable across compactions: the base
dataset gets ids ``0..N-1`` and each insert allocates fresh ids upward.
Query results (``range_pairs`` data column, kNN indices) are global ids.
``IndexView`` is the consistent read snapshot a request pins: compacting
under a pinned view changes none of its arrays (all mutation is
copy-on-write), which is what makes answers bit-identical across the swap.

``save``/``load`` persist the derived snapshot state (permutation, grid
arrays, tile plan) AND the churn state (global ids, delta buffer,
tombstones, the id->coordinates log) in one ``.npz``, so a restarted server
resumes the exact epoch it left -- stale snapshot, pending delta and all --
and serves bit-identically (``SelfJoinEngine.from_prebuilt`` only re-places
arrays on device).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.engine import QueryPlanTables, SelfJoinEngine
from repro.core.grid import GridIndex, TilePlan, bucket_rows, pad_axis0
from repro.core.reorder import apply_reorder
from repro.core.snapshot import GridSnapshot
from repro.core.tuning import select_k
from repro.core.types import EngineConfig, SelfJoinConfig

_SAVE_VERSION = 2

_GRID_ARRAYS = (
    "origin", "cells_per_dim", "strides", "point_order", "pts_sorted",
    "cell_coords", "cell_ids", "cell_start", "cell_count",
)
_PLAN_ARRAYS = ("tile_start", "tile_len", "tile_cell", "pair_a", "pair_b")

# smallest device row bucket for the delta/tombstone aux tables: churny
# streams grow through few shapes before settling into pow2 doubling
_AUX_MIN_ROWS = 8


def _npz_path(path) -> str:
    path = str(path)
    return path if path.endswith(".npz") else path + ".npz"


@dataclasses.dataclass(frozen=True)
class IndexView:
    """One request's consistent read snapshot of a mutable index.

    Pinned at request entry (``QueryService``): the frozen ``GridSnapshot``
    plus the churn arrays *as of that instant*.  All index mutation is
    copy-on-write (arrays are replaced, never written in place), so a view
    stays valid -- and keeps answering identically -- while inserts,
    deletes, or a ``compact`` swap land behind it.
    """

    epoch: int                    # compaction epoch the view pins
    snapshot: GridSnapshot        # the frozen base index
    snap_ids: np.ndarray          # (N,) int64 global id per snapshot row
    delta_ids: np.ndarray         # (m,) int64 global ids of live delta points
    delta_pts: np.ndarray         # (m, n) f32 their coords, ORIGINAL frame
    dead_rows: np.ndarray         # (d,) int64 tombstoned snapshot ROWS
    dead_pts: np.ndarray          # (d, n) f32 their coords, ORIGINAL frame
    delta_dev: Optional[jnp.ndarray]   # (pow2 >= m, n) f32 device delta table
    dead_dev: Optional[jnp.ndarray]    # (pow2 >= d, n) f32 device dead table
    live_count: int               # |snapshot| - |tombstones| + |delta|
    live_bounds: Tuple[np.ndarray, np.ndarray]  # per-dim (min, max) of the
                                  # live set, ORIGINAL frame, float64

    @property
    def delta_size(self) -> int:
        return int(self.delta_ids.shape[0])

    @property
    def tombstone_count(self) -> int:
        return int(self.dead_rows.shape[0])


@dataclasses.dataclass(frozen=True)
class PendingCompact:
    """The pure build half of a compaction, produced off the serving path.

    ``apply_compact`` refuses a pending snapshot whose ``mut_version`` no
    longer matches the index (mutations landed since the build started);
    the caller re-prepares against the new state.
    """

    snapshot: GridSnapshot
    snap_ids: np.ndarray
    mut_version: int


class SimilarityIndex:
    """Mutable, device-resident index over one evolving dataset.

    An ownership layer over ``SelfJoinEngine``: the engine's snapshot holds
    the REORDER permutation, the grid, the tile plan and the device-resident
    packed tiles; this class adds auto-k selection at build time, the
    insert/delete/compact churn machinery, and the persistence contract a
    serving process needs.

    ``k_candidates`` (optional) runs the paper's Sec. 5.6 memory-op model
    (``tuning.select_k``) over the given candidate list and bakes the winner
    into the stored config, so a restarted server never re-tunes.
    """

    def __init__(
        self,
        d: np.ndarray,
        config: SelfJoinConfig,
        engine_config: Optional[EngineConfig] = None,
        *,
        k_candidates: Optional[Sequence[int]] = None,
        auto_compact_fraction: Optional[float] = None,
    ):
        pts = np.ascontiguousarray(np.asarray(d, dtype=np.float32))
        if k_candidates is not None and pts.shape[0] > 2:
            k = select_k(
                pts, config.eps, list(k_candidates),
                reorder=config.reorder, sample_frac=config.sample_frac,
                tile_size=config.tile_size,
            )
            config = dataclasses.replace(config, k=k)
        if auto_compact_fraction is not None and auto_compact_fraction <= 0:
            raise ValueError(
                f"auto_compact_fraction must be > 0, "
                f"got {auto_compact_fraction}"
            )
        self.engine = SelfJoinEngine(pts, config, engine_config)
        n = pts.shape[0]
        self._init_churn_state(
            snap_ids=np.arange(n, dtype=np.int64),
            id_pts=pts.copy(),
            next_id=n,
            epoch=0,
            auto_compact_fraction=auto_compact_fraction,
        )

    def _init_churn_state(
        self,
        snap_ids: np.ndarray,
        id_pts: np.ndarray,
        next_id: int,
        epoch: int,
        delta_ids: Optional[np.ndarray] = None,
        delta_pts: Optional[np.ndarray] = None,
        dead_ids: Optional[np.ndarray] = None,
        auto_compact_fraction: Optional[float] = None,
    ) -> None:
        n_dims = self.engine.num_dims
        # delta-buffer spill policy: when set, insert() auto-compacts once
        # the delta outgrows this fraction of the snapshot (DESIGN.md #10)
        self.auto_compact_fraction = (
            None if auto_compact_fraction is None
            else float(auto_compact_fraction)
        )
        self.auto_compactions = 0     # spill-policy-triggered compactions
        self._snap_ids = np.asarray(snap_ids, np.int64)      # ascending
        self._id_pts = np.asarray(id_pts, np.float32)        # (next_id, n) log
        self._next_id = int(next_id)
        self.epoch = int(epoch)
        empty_ids = np.zeros(0, np.int64)
        empty_pts = np.zeros((0, n_dims), np.float32)
        self._delta_ids = (
            empty_ids if delta_ids is None else np.asarray(delta_ids, np.int64)
        )
        self._delta_pts = (
            empty_pts if delta_pts is None else np.asarray(delta_pts, np.float32)
        )
        self._dead_ids = (                                   # sorted, snapshot-side
            empty_ids if dead_ids is None else np.sort(np.asarray(dead_ids, np.int64))
        )
        # copy-on-write version counter: bumps on every mutation, keys the
        # device-table and live-bounds caches
        self._mut_version = 0
        self._delta_dev_cache: Optional[Tuple[int, jnp.ndarray]] = None
        self._dead_dev_cache: Optional[Tuple[int, jnp.ndarray]] = None
        self._bounds_cache = None

    @classmethod
    def _wrap(cls, engine: SelfJoinEngine) -> "SimilarityIndex":
        self = object.__new__(cls)
        self.engine = engine
        n = engine.num_points
        self._init_churn_state(
            snap_ids=np.arange(n, dtype=np.int64),
            id_pts=engine.snapshot.pts.copy(),
            next_id=n,
            epoch=0,
        )
        return self

    # -- introspection ----------------------------------------------------

    @property
    def config(self) -> SelfJoinConfig:
        return self.engine.config

    @property
    def num_points(self) -> int:
        """LIVE point count: snapshot minus tombstones plus delta."""
        return self.live_count

    @property
    def live_count(self) -> int:
        return (
            int(self._snap_ids.shape[0])
            - int(self._dead_ids.shape[0])
            + int(self._delta_ids.shape[0])
        )

    @property
    def delta_size(self) -> int:
        return int(self._delta_ids.shape[0])

    @property
    def tombstone_count(self) -> int:
        return int(self._dead_ids.shape[0])

    @property
    def num_dims(self) -> int:
        return self.engine.num_dims

    @property
    def points(self) -> np.ndarray:
        """The SNAPSHOT dataset (original frame); excludes the delta buffer."""
        return self.engine.snapshot.pts

    @property
    def perm(self) -> Optional[np.ndarray]:
        """The persisted REORDER dim permutation (None when reorder=False)."""
        return self.engine.snapshot.perm

    @property
    def index_eps(self) -> Optional[float]:
        """Radius the current grid was built for (queries at <= this reuse it)."""
        return self.engine.snapshot.index_eps

    def coords_of(self, ids: np.ndarray) -> np.ndarray:
        """Coordinates (original frame, f32) of global ids, live or dead.

        The id->coordinates log is append-only and ids are never recycled,
        so this is stable under concurrent mutation and valid for any id a
        pinned view ever returned.
        """
        return self._id_pts[np.asarray(ids, np.int64)]

    def transform_queries(self, q: np.ndarray) -> np.ndarray:
        """Apply the dataset's REORDER permutation to external query points."""
        if self.perm is None:
            return np.asarray(q)
        return apply_reorder(q, self.perm)

    def bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-dimension (min, max) of the SNAPSHOT points, REORDERED frame.

        Kept for snapshot-level consumers; the serving tier's kNN cap uses
        ``live_bounds`` (original frame, live set) instead.
        """
        return self.engine.snapshot.data_bounds

    def live_bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-dim (min, max) of the LIVE set, original frame, float64.

        Cached per mutation version: the serving tier reads this on every
        kNN request to cap its eps expansion, and the live set only changes
        when a mutation lands.
        """
        got = self._bounds_cache
        if got is not None and got[0] == self._mut_version:
            return got[1]
        parts = []
        snap_pts = self.engine.snapshot.pts
        if self._dead_ids.shape[0]:
            alive = np.ones(snap_pts.shape[0], bool)
            alive[np.searchsorted(self._snap_ids, self._dead_ids)] = False
            snap_pts = snap_pts[alive]
        if snap_pts.shape[0]:
            parts.append(snap_pts)
        if self._delta_pts.shape[0]:
            parts.append(self._delta_pts)
        if parts:
            live = np.concatenate(parts).astype(np.float64)
            val = (live.min(axis=0), live.max(axis=0))
        else:
            z = np.zeros(self.num_dims, np.float64)
            val = (z, z)
        self._bounds_cache = (self._mut_version, val)
        return val

    def prepare_query(
        self,
        q: np.ndarray,
        eps: Optional[float] = None,
        *,
        pad_queries_to: Optional[int] = None,
    ) -> Optional[QueryPlanTables]:
        """The engine's bipartite query-plan API (original-frame queries).

        Covers the SNAPSHOT only; a mutated index's delta/tombstone
        epilogue is the service's job (``QueryService``).
        """
        return self.engine.prepare_query(q, eps, pad_queries_to=pad_queries_to)

    # -- mutation ----------------------------------------------------------

    def _bump(self) -> None:
        self._mut_version += 1

    def insert(self, points: np.ndarray) -> np.ndarray:
        """Append new points; returns their freshly allocated global ids.

        The points land in the delta buffer -- no grid rebuild, no compiled
        program invalidated -- and are visible to the very next query (the
        service dense-joins the delta against every batch).  ``compact()``
        eventually folds them into a fresh snapshot; with
        ``auto_compact_fraction`` set, that happens here automatically once
        the delta outgrows that fraction of the snapshot (the spill
        policy), so answers before and after the spill stay bit-identical
        by the compaction contract.
        """
        pts = np.ascontiguousarray(np.asarray(points, dtype=np.float32))
        if pts.ndim != 2 or pts.shape[1] != self.num_dims:
            raise ValueError(
                f"expected (m, {self.num_dims}) points, got {pts.shape}"
            )
        m = pts.shape[0]
        ids = np.arange(self._next_id, self._next_id + m, dtype=np.int64)
        if m == 0:
            return ids
        with obs.span("index.insert", "index", m=m, delta=self.delta_size):
            self._id_pts = np.concatenate([self._id_pts, pts])
            self._delta_ids = np.concatenate([self._delta_ids, ids])
            self._delta_pts = np.concatenate([self._delta_pts, pts])
            self._next_id += m
            self._bump()
            obs.inc("index_inserts_total", m)
            self._maybe_auto_compact()
        return ids

    def _maybe_auto_compact(self) -> None:
        """The delta-buffer spill policy: compact when the delta outgrows
        ``auto_compact_fraction`` of the snapshot (floor 1 row, so an index
        born empty still converges instead of thrashing)."""
        frac = self.auto_compact_fraction
        if frac is None:
            return
        threshold = frac * max(int(self._snap_ids.shape[0]), 1)
        if self.delta_size > threshold:
            with obs.span(
                "index.auto_compact", "index",
                delta=self.delta_size, snapshot=int(self._snap_ids.shape[0]),
            ):
                self.apply_compact(self.prepare_compact())
            self.auto_compactions += 1
            obs.inc("index_auto_compactions_total")

    def delete(self, ids) -> int:
        """Delete live points by global id; returns how many were removed.

        Snapshot points get a tombstone (masked out of every answer at the
        query epilogue until ``compact`` drops the row); delta points are
        simply removed from the buffer.  Raises ``KeyError`` if any id is
        unknown or already deleted -- duplicates within one call are
        collapsed first.
        """
        ids = np.unique(np.asarray(ids, dtype=np.int64).reshape(-1))
        if ids.size == 0:
            return 0
        in_delta = np.isin(ids, self._delta_ids)
        snap_side = ids[~in_delta]
        if snap_side.size:
            pos = np.searchsorted(self._snap_ids, snap_side)
            pos_ok = pos < self._snap_ids.shape[0]
            known = np.zeros(snap_side.shape[0], bool)
            known[pos_ok] = (
                self._snap_ids[pos[pos_ok]] == snap_side[pos_ok]
            )
            bad = snap_side[~known | np.isin(snap_side, self._dead_ids)]
            if bad.size:
                raise KeyError(
                    f"cannot delete unknown or already-deleted ids {bad.tolist()}"
                )
        with obs.span("index.delete", "index", m=int(ids.size)):
            if in_delta.any():
                keep = ~np.isin(self._delta_ids, ids)
                self._delta_ids = self._delta_ids[keep]
                self._delta_pts = self._delta_pts[keep]
            if snap_side.size:
                self._dead_ids = np.union1d(self._dead_ids, snap_side)
            self._bump()
            obs.inc("index_deletes_total", int(ids.size))
        return int(ids.size)

    def prepare_compact(self) -> PendingCompact:
        """Pure build half of a compaction: a fresh snapshot over the live set.

        No index state changes -- safe to run on a background thread while
        the foreground keeps serving (and mutating).  The rebuilt snapshot
        keeps the current permutation frame and carries the current
        snapshot's shape buckets forward as floors, so applying it
        invalidates no warm executable whose bucket still fits.
        """
        with obs.span(
            "index.prepare_compact", "index",
            live=self.live_count, delta=self.delta_size,
            tombstones=int(self._dead_ids.shape[0]),
        ):
            old = self.engine.snapshot
            alive = np.ones(self._snap_ids.shape[0], bool)
            if self._dead_ids.shape[0]:
                alive[np.searchsorted(self._snap_ids, self._dead_ids)] = False
            live_ids = np.concatenate([self._snap_ids[alive], self._delta_ids])
            srt = np.argsort(live_ids, kind="stable")
            live_ids = live_ids[srt]
            live_pts = self.coords_of(live_ids)
            perm = old.perm if old.num_points else "auto"
            snapshot = GridSnapshot.build(
                live_pts, self.config, old.index_eps,
                perm=perm,
                min_tile_rows=old.tile_rows,
                min_point_rows=old.point_rows,
                min_dense_rows=old.dense_rows,
            )
            return PendingCompact(
                snapshot=snapshot,
                snap_ids=live_ids,
                mut_version=self._mut_version,
            )

    def apply_compact(self, pending: PendingCompact) -> None:
        """Atomically swap a prepared snapshot in and reset the churn state.

        One reference assignment plus array replacements -- a request that
        pinned an ``IndexView`` before this call keeps its old epoch and
        answers unchanged.  Raises ``RuntimeError`` if mutations landed
        since ``prepare_compact`` (the pending snapshot is stale; re-prepare).
        """
        if pending.mut_version != self._mut_version:
            raise RuntimeError(
                "index mutated since prepare_compact(); rebuild the pending "
                "snapshot against the current state"
            )
        with obs.span(
            "index.apply_compact", "index",
            epoch=self.epoch + 1, n=int(pending.snap_ids.shape[0]),
        ):
            self.engine.swap_snapshot(pending.snapshot)
            self._snap_ids = pending.snap_ids
            self._delta_ids = np.zeros(0, np.int64)
            self._delta_pts = np.zeros((0, self.num_dims), np.float32)
            self._dead_ids = np.zeros(0, np.int64)
            self.epoch += 1
            self._bump()
            obs.inc("index_compactions_total")

    def compact(self) -> "SimilarityIndex":
        """Rebuild the snapshot over the live set and swap it in (both halves)."""
        self.apply_compact(self.prepare_compact())
        return self

    # -- pinned views ------------------------------------------------------

    def _delta_device(self) -> Optional[jnp.ndarray]:
        """Delta coords on device, pow2-padded rows; None when empty."""
        m = self._delta_pts.shape[0]
        if m == 0:
            return None
        got = self._delta_dev_cache
        if got is None or got[0] != self._mut_version:
            rows = bucket_rows(m, _AUX_MIN_ROWS)
            got = (self._mut_version, jnp.asarray(pad_axis0(self._delta_pts, rows)))
            self._delta_dev_cache = got
        return got[1]

    def _dead_device(self) -> Optional[jnp.ndarray]:
        """Tombstoned coords on device, pow2-padded rows; None when empty."""
        d = self._dead_ids.shape[0]
        if d == 0:
            return None
        got = self._dead_dev_cache
        if got is None or got[0] != self._mut_version:
            rows = bucket_rows(d, _AUX_MIN_ROWS)
            got = (
                self._mut_version,
                jnp.asarray(pad_axis0(self._id_pts[self._dead_ids], rows)),
            )
            self._dead_dev_cache = got
        return got[1]

    def view(self) -> IndexView:
        """Pin the current epoch: the consistent read snapshot of one request."""
        dead_rows = np.searchsorted(self._snap_ids, self._dead_ids)
        return IndexView(
            epoch=self.epoch,
            snapshot=self.engine.snapshot,
            snap_ids=self._snap_ids,
            delta_ids=self._delta_ids,
            delta_pts=self._delta_pts,
            dead_rows=dead_rows.astype(np.int64),
            dead_pts=self._id_pts[self._dead_ids],
            delta_dev=self._delta_device(),
            dead_dev=self._dead_device(),
            live_count=self.live_count,
            live_bounds=self.live_bounds(),
        )

    # -- persistence -------------------------------------------------------

    def save(self, path) -> str:
        """Write dataset + index + churn state to ``path`` (.npz); return it."""
        eng = self.engine
        snap = eng.snapshot
        meta = {
            "version": _SAVE_VERSION,
            "config": dataclasses.asdict(eng.config),
            "index_eps": snap.index_eps,
            "has_perm": snap.perm is not None,
            "has_index": snap.grid is not None,
            "epoch": self.epoch,
            "next_id": self._next_id,
            "auto_compact_fraction": self.auto_compact_fraction,
        }
        arrays = {
            "pts": snap.pts,
            "snap_ids": self._snap_ids,
            "id_pts": self._id_pts,
            "delta_ids": self._delta_ids,
            "delta_pts": self._delta_pts,
            "dead_ids": self._dead_ids,
        }
        if snap.perm is not None:
            arrays["perm"] = np.asarray(snap.perm)
        if snap.grid is not None:
            g, p = snap.grid, snap.plan
            meta["grid"] = {
                "eps": g.eps, "k": g.k, "n": g.n, "u_dim": g.u_dim,
            }
            meta["plan"] = {
                "tile_size": p.tile_size,
                "num_tile_pairs_total": p.num_tile_pairs_total,
                "num_candidates": p.num_candidates,
            }
            for name in _GRID_ARRAYS:
                arrays[f"grid_{name}"] = getattr(g, name)
            for name in _PLAN_ARRAYS:
                arrays[f"plan_{name}"] = getattr(p, name)
        path = _npz_path(path)
        with open(path, "wb") as f:
            np.savez_compressed(f, meta=np.array(json.dumps(meta)), **arrays)
        return path

    @classmethod
    def load(
        cls, path, engine_config: Optional[EngineConfig] = None
    ) -> "SimilarityIndex":
        """Rebuild the index from ``save`` output without host recompute."""
        with np.load(_npz_path(path), allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            if meta["version"] != _SAVE_VERSION:
                raise ValueError(
                    f"unsupported index save version {meta['version']}"
                )
            pts = z["pts"]
            perm = z["perm"] if meta["has_perm"] else None
            grid = plan = None
            if meta["has_index"]:
                grid = GridIndex(
                    **meta["grid"],
                    **{name: z[f"grid_{name}"] for name in _GRID_ARRAYS},
                )
                plan = TilePlan(
                    **meta["plan"],
                    **{name: z[f"plan_{name}"] for name in _PLAN_ARRAYS},
                )
            config = SelfJoinConfig(**meta["config"])
            engine = SelfJoinEngine.from_prebuilt(
                pts, perm, grid, plan, meta["index_eps"], config, engine_config
            )
            self = object.__new__(cls)
            self.engine = engine
            self._init_churn_state(
                snap_ids=z["snap_ids"],
                id_pts=z["id_pts"],
                next_id=meta["next_id"],
                epoch=meta["epoch"],
                delta_ids=z["delta_ids"],
                delta_pts=z["delta_pts"],
                dead_ids=z["dead_ids"],
                # additive meta key: absent in version-2 saves from before
                # the spill policy existed
                auto_compact_fraction=meta.get("auto_compact_fraction"),
            )
        return self
