"""Persistent device-resident similarity index (DESIGN.md #8).

``SimilarityIndex`` is the build-once half of the serving tier: it runs the
paper's whole index-construction pipeline -- REORDER (persisting the dim
permutation so incoming queries are permuted identically), ``select_k``
auto-selection of the indexed dimension count, grid construction, and the
packed tile table placed on device once -- and then answers nothing itself:
``QueryService`` (``service.py``) serves queries over it.

``save``/``load`` persist the *derived* index state (permutation, grid
arrays, tile plan) next to the dataset in one ``.npz``, so a server process
can restart without re-running REORDER or the grid build and the restarted
index serves queries bit-identically to the one that was saved
(``SelfJoinEngine.from_prebuilt`` only re-places the arrays on device).
The full ``SelfJoinConfig`` -- including the ``execution`` tier-dispatch
mode (DESIGN.md #9) -- round-trips through the JSON metadata, so a
restarted server makes the same dense/indexed dispatch decisions as the
one that was saved; the dense tier's tables are derived (re-tiled from the
persisted ``pts_sorted``) and need no arrays of their own.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import QueryPlanTables, SelfJoinEngine
from repro.core.grid import GridIndex, TilePlan
from repro.core.reorder import apply_reorder
from repro.core.tuning import select_k
from repro.core.types import EngineConfig, SelfJoinConfig

_SAVE_VERSION = 1

_GRID_ARRAYS = (
    "origin", "cells_per_dim", "strides", "point_order", "pts_sorted",
    "cell_coords", "cell_ids", "cell_start", "cell_count",
)
_PLAN_ARRAYS = ("tile_start", "tile_len", "tile_cell", "pair_a", "pair_b")


def _npz_path(path) -> str:
    path = str(path)
    return path if path.endswith(".npz") else path + ".npz"


class SimilarityIndex:
    """Build-once, device-resident index over one dataset.

    A thin ownership layer over ``SelfJoinEngine``: the engine holds the
    REORDER permutation, the grid, the tile plan and the device-resident
    packed tiles; this class adds auto-k selection at build time and the
    persistence contract a serving process needs.

    ``k_candidates`` (optional) runs the paper's Sec. 5.6 memory-op model
    (``tuning.select_k``) over the given candidate list and bakes the winner
    into the stored config, so a restarted server never re-tunes.
    """

    def __init__(
        self,
        d: np.ndarray,
        config: SelfJoinConfig,
        engine_config: Optional[EngineConfig] = None,
        *,
        k_candidates: Optional[Sequence[int]] = None,
    ):
        pts = np.ascontiguousarray(np.asarray(d, dtype=np.float32))
        if k_candidates is not None and pts.shape[0] > 2:
            k = select_k(
                pts, config.eps, list(k_candidates),
                reorder=config.reorder, sample_frac=config.sample_frac,
                tile_size=config.tile_size,
            )
            config = dataclasses.replace(config, k=k)
        self.engine = SelfJoinEngine(pts, config, engine_config)

    @classmethod
    def _wrap(cls, engine: SelfJoinEngine) -> "SimilarityIndex":
        self = object.__new__(cls)
        self.engine = engine
        return self

    # -- introspection ----------------------------------------------------

    @property
    def config(self) -> SelfJoinConfig:
        return self.engine.config

    @property
    def num_points(self) -> int:
        return self.engine.num_points

    @property
    def num_dims(self) -> int:
        return self.engine.num_dims

    @property
    def points(self) -> np.ndarray:
        """The indexed dataset, original row order and coordinate frame."""
        return self.engine._pts

    @property
    def perm(self) -> Optional[np.ndarray]:
        """The persisted REORDER dim permutation (None when reorder=False)."""
        return self.engine._perm

    @property
    def index_eps(self) -> Optional[float]:
        """Radius the current grid was built for (queries at <= this reuse it)."""
        return self.engine._index_eps

    def transform_queries(self, q: np.ndarray) -> np.ndarray:
        """Apply the dataset's REORDER permutation to external query points."""
        if self.perm is None:
            return np.asarray(q)
        return apply_reorder(q, self.perm)

    def bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-dimension (min, max) of the dataset, REORDERED frame, float64.

        Delegates to ``GridIndex.data_bounds`` (the grid stores the sorted
        reordered points); combine only with queries passed through
        ``transform_queries`` so both sides share the frame.
        """
        if self.engine.grid is not None:
            return self.engine.grid.data_bounds
        z = np.zeros(self.num_dims, np.float64)
        return z, z

    def prepare_query(
        self,
        q: np.ndarray,
        eps: Optional[float] = None,
        *,
        pad_queries_to: Optional[int] = None,
    ) -> Optional[QueryPlanTables]:
        """The engine's bipartite query-plan API (original-frame queries)."""
        return self.engine.prepare_query(q, eps, pad_queries_to=pad_queries_to)

    # -- persistence -------------------------------------------------------

    def save(self, path) -> str:
        """Write dataset + derived index state to ``path`` (.npz); return it."""
        eng = self.engine
        meta = {
            "version": _SAVE_VERSION,
            "config": dataclasses.asdict(eng.config),
            "index_eps": eng._index_eps,
            "has_perm": eng._perm is not None,
            "has_index": eng.grid is not None,
        }
        arrays = {"pts": eng._pts}
        if eng._perm is not None:
            arrays["perm"] = np.asarray(eng._perm)
        if eng.grid is not None:
            g, p = eng.grid, eng.plan
            meta["grid"] = {
                "eps": g.eps, "k": g.k, "n": g.n, "u_dim": g.u_dim,
            }
            meta["plan"] = {
                "tile_size": p.tile_size,
                "num_tile_pairs_total": p.num_tile_pairs_total,
                "num_candidates": p.num_candidates,
            }
            for name in _GRID_ARRAYS:
                arrays[f"grid_{name}"] = getattr(g, name)
            for name in _PLAN_ARRAYS:
                arrays[f"plan_{name}"] = getattr(p, name)
        path = _npz_path(path)
        with open(path, "wb") as f:
            np.savez_compressed(f, meta=np.array(json.dumps(meta)), **arrays)
        return path

    @classmethod
    def load(
        cls, path, engine_config: Optional[EngineConfig] = None
    ) -> "SimilarityIndex":
        """Rebuild the index from ``save`` output without host recompute."""
        with np.load(_npz_path(path), allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            if meta["version"] != _SAVE_VERSION:
                raise ValueError(
                    f"unsupported index save version {meta['version']}"
                )
            pts = z["pts"]
            perm = z["perm"] if meta["has_perm"] else None
            grid = plan = None
            if meta["has_index"]:
                grid = GridIndex(
                    **meta["grid"],
                    **{name: z[f"grid_{name}"] for name in _GRID_ARRAYS},
                )
                plan = TilePlan(
                    **meta["plan"],
                    **{name: z[f"plan_{name}"] for name in _PLAN_ARRAYS},
                )
            config = SelfJoinConfig(**meta["config"])
            engine = SelfJoinEngine.from_prebuilt(
                pts, perm, grid, plan, meta["index_eps"], config, engine_config
            )
        return cls._wrap(engine)
