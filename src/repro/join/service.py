"""Batched online query serving over a ``SimilarityIndex`` (DESIGN.md #8).

``QueryService`` answers three request kinds against one resident index:

  ``range_count(q, eps)``  per-query counts of indexed points within eps;
  ``range_pairs(q, eps)``  the materialized (query row, data id) pairs;
  ``knn(q, k)``            k nearest indexed points per query, found by
                           adaptive eps expansion on the count program
                           (double the radius until every query holds >= k
                           candidates, then one pairs pass + exact top-k).

Compilation discipline -- the property that makes this a *service* rather
than a loop of one-shot joins: request batches are padded to power-of-two
shape buckets (``SelfJoinEngine.prepare_query(pad_queries_to=...)``), eps is
always a traced scalar, and the two chunk programs are jitted once per
service with a host-side trace counter in the traced body, so an arbitrary
request stream compiles at most one count and one pairs executable per
bucket.  ``ServiceStats.num_traces`` reports it per request and
``QueryService.total`` accumulates it across the stream -- the serving
analogue of the fused ring's ``fused_traces == 1`` contract.

Execution tiers (DESIGN.md #9): every request batch flows through the
engine's cost-model dispatch (``SelfJoinConfig.execution``), so a
high-dimensional stream where the grid has lost its filtering power is
served by the dense matmul tier.  The tier is part of each executable's
static trace key (``backend``/``shortc``), so a mixed stream straddling the
dispatch boundary compiles at most one count and one pairs executable per
shape bucket *per tier*; ``ServiceStats`` records the tier served and the
cost model's two estimates.

kNN tie-breaking is deterministic: neighbours sort by (distance, data id),
and queries with fewer than k reachable neighbours (k >= |D|) pad with
id -1 / distance +inf.  The eps expansion is capped at the diagonal of the
joint query/data bounding box, which provably contains every candidate, so
termination never depends on the data distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    QueryPlanTables,
    count_chunk_step,
    pairs_chunk_step,
)
from repro.join.index import SimilarityIndex
from repro.kernels import ops

_MAX_HITCAP_RETRIES = 8


@dataclasses.dataclass
class ServiceStats:
    """Per-request (and, via ``QueryService.total``, cumulative) counters."""

    num_requests: int = 0        # requests served (1 per response object)
    num_queries: int = 0         # query rows in the batch
    bucket: int = 0              # padded slot count the batch was served in
    eps: float = 0.0             # final radius evaluated
    eps_rounds: int = 0          # kNN eps-expansion count passes (1 = no growth)
    num_traces: int = 0          # NEW chunk-program traces this request caused
    num_device_dispatches: int = 0  # chunk-program launches
    num_candidates: int = 0      # point comparisons the chosen tier evaluated
    num_results: int = 0         # neighbours counted / pairs returned
    index_rebuilds: int = 0      # grid rebuilds forced by eps above the index radius
    execution: str = ""          # tier that served this request ("mixed" across
                                 # requests/eps rounds that disagree)
    cost_indexed: float = 0.0    # summed cost-model indexed-tier estimates
    cost_dense: float = 0.0      # summed cost-model dense-tier estimates

    def record_tier(self, execution: str, ci: float, cd: float) -> None:
        if self.execution and self.execution != execution:
            self.execution = "mixed"
        else:
            self.execution = execution
        self.cost_indexed += ci
        self.cost_dense += cd

    def accumulate(self, other: "ServiceStats") -> None:
        self.num_requests += other.num_requests
        self.num_queries += other.num_queries
        self.bucket = max(self.bucket, other.bucket)
        self.eps = max(self.eps, other.eps)
        self.eps_rounds += other.eps_rounds
        self.num_traces += other.num_traces
        self.num_device_dispatches += other.num_device_dispatches
        self.num_candidates += other.num_candidates
        self.num_results += other.num_results
        self.index_rebuilds += other.index_rebuilds
        if other.execution:
            self.record_tier(
                other.execution, other.cost_indexed, other.cost_dense
            )


@dataclasses.dataclass
class RangeCountResult:
    counts: np.ndarray           # (nq,) int64, batch row order
    stats: ServiceStats


@dataclasses.dataclass
class RangePairsResult:
    pairs: np.ndarray            # (R, 2) int32 (query row, data id), lexsorted
    counts: np.ndarray           # (nq,) int64
    stats: ServiceStats


@dataclasses.dataclass
class KnnResult:
    indices: np.ndarray          # (nq, k) int64 data ids, -1 where < k exist
    distances: np.ndarray        # (nq, k) float64, +inf where < k exist
    counts: np.ndarray           # (nq,) int64 candidates at the final radius
    stats: ServiceStats


class QueryService:
    """Batched range + kNN serving over one ``SimilarityIndex``.

    Queries are given in ORIGINAL coordinates; the service permutes them
    with the index's persisted REORDER permutation.  A radius above the
    index build radius transparently rebuilds the grid (host-side, counted
    in ``stats.index_rebuilds``); radii at or below it reuse everything.
    """

    def __init__(self, index: SimilarityIndex, *, min_bucket: int = 16):
        if min_bucket < 1:
            raise ValueError("min_bucket must be >= 1")
        self.index = index
        self.min_bucket = int(min_bucket)
        self.total = ServiceStats()
        self.buckets_used: Set[int] = set()
        self._trace_count = 0
        # the radius the service PINS the index at: requests above it grow
        # the grid temporarily, and _finish restores this one (see below)
        self._serve_eps = index.index_eps

        cfg = index.config
        eng = index.engine.engine
        self._count_chunk = eng.count_chunk
        self._pairs_chunk = eng.pairs_chunk

        # The service's two executables, jitted once per service instance.
        # The bodies run ONLY when XLA traces a new (bucket) shape, so the
        # counter increments measure exactly the compile-reuse contract.
        # ``backend``/``shortc`` are static: a stream that straddles the
        # dense/indexed dispatch boundary compiles at most one executable
        # per shape bucket PER TIER (the tile-table shapes differ between
        # tiers anyway, so the tier is already part of the trace key).
        def _count_step(
            counts, tiles, tile_len, tile_start, pa, pb, real, eps,
            *, backend, shortc,
        ):
            self._trace_count += 1
            counts, _ = count_chunk_step(
                counts, jnp.zeros((), jnp.int32),
                tiles, tile_len, tile_start, pa, pb, real, eps,
                dim_block=cfg.dim_block, shortc=shortc,
                backend=backend, interpret=eng.interpret,
            )
            return counts

        def _pairs_step(
            buf, offset, max_hits, tiles, tile_len, tile_start, order,
            pa, pb, real, eps, *, hit_cap, backend,
        ):
            self._trace_count += 1
            return pairs_chunk_step(
                buf, offset, max_hits, tiles, tile_len, tile_start, order,
                pa, pb, real, eps,
                hit_cap=hit_cap, dim_block=cfg.dim_block,
                backend=backend, interpret=eng.interpret,
            )

        self._count_step = jax.jit(
            _count_step, static_argnames=("backend", "shortc")
        )
        self._pairs_step = jax.jit(
            _pairs_step, static_argnames=("hit_cap", "backend")
        )

    # -- bucketing ---------------------------------------------------------

    def bucket_size(self, nq: int) -> int:
        """Power-of-two slot count (>= min_bucket) the batch is padded to."""
        return 1 << (max(int(nq), self.min_bucket) - 1).bit_length()

    # -- internal execution ------------------------------------------------

    def _prepare(
        self, q: np.ndarray, eps: float, stats: ServiceStats
    ) -> Optional[QueryPlanTables]:
        before = self.index.index_eps
        bucket = self.bucket_size(q.shape[0])
        tab = self.index.prepare_query(q, eps, pad_queries_to=bucket)
        if self.index.index_eps != before:
            stats.index_rebuilds += 1
        stats.bucket = bucket
        self.buckets_used.add(bucket)
        if tab is not None:
            stats.record_tier(tab.execution, tab.cost_indexed, tab.cost_dense)
        return tab

    def _tier_kwargs(self, tab: QueryPlanTables) -> dict:
        cfg = self.index.config
        return {
            "backend": ops.backend_name(tab.execution, cfg.use_pallas),
            "shortc": cfg.shortc and tab.execution == "indexed",
        }

    def _run_counts(
        self, tab: QueryPlanTables, eps: float, stats: ServiceStats
    ) -> np.ndarray:
        tier = self._tier_kwargs(tab)
        counts_sorted = jnp.zeros(tab.n_slots, jnp.int32)
        for pa, pb, real in tab.chunks(self._count_chunk):
            counts_sorted = self._count_step(
                counts_sorted, tab.tiles, tab.tile_len, tab.tile_start,
                pa, pb, real, jnp.float32(eps), **tier,
            )
            stats.num_device_dispatches += 1
        stats.num_candidates += tab.num_candidates
        cs = np.asarray(counts_sorted)
        counts = np.zeros(tab.nq, np.int64)
        counts[tab.qplan.q_order] = cs[: tab.nq]
        return counts

    def _run_pairs(
        self, tab: QueryPlanTables, eps: float, total: int, stats: ServiceStats
    ) -> np.ndarray:
        """One pairs pass sized exactly from the known count total."""
        t = int(self.index.config.tile_size)
        backend = self._tier_kwargs(tab)["backend"]
        flat_per_chunk = self._pairs_chunk * t * t
        hit_cap = min(flat_per_chunk, 4096)
        cap = 1 << (max(int(total), 1) - 1).bit_length()  # pow2: bounded trace keys
        for _ in range(_MAX_HITCAP_RETRIES + 1):
            buf = jnp.zeros((cap + hit_cap, 2), jnp.int32)
            offset = jnp.zeros((), jnp.int32)
            max_hits = jnp.zeros((), jnp.int32)
            for pa, pb, real in tab.chunks(self._pairs_chunk):
                buf, offset, max_hits = self._pairs_step(
                    buf, offset, max_hits,
                    tab.tiles, tab.tile_len, tab.tile_start, tab.order,
                    pa, pb, real, jnp.float32(eps), hit_cap=hit_cap,
                    backend=backend,
                )
                stats.num_device_dispatches += 1
            if int(max_hits) <= hit_cap:
                break
            # a single chunk outgrew the rank window: widen to the observed
            # maximum (pow2 so the retry shapes stay bounded) and redo
            hit_cap = min(
                flat_per_chunk, 1 << (int(max_hits) - 1).bit_length()
            )
        num = int(offset)
        if num != total:
            raise RuntimeError(
                f"pairs pass found {num} pairs but the count pass said {total}"
            )
        pairs = np.asarray(buf[:num])
        if num:
            srt = np.lexsort((pairs[:, 1], pairs[:, 0]))
            pairs = np.ascontiguousarray(pairs[srt])
        return pairs

    def _finish(self, stats: ServiceStats, traces_before: int) -> ServiceStats:
        # restore the build-radius index if this request grew it (a kNN
        # expansion or an over-radius range query): a coarse large-eps grid
        # left behind would silently cost every later request its candidate
        # filtering AND its warm per-bucket executables (the tile-table
        # shapes change).  The rebuild is deterministic, so the restored
        # grid re-hits the executables compiled before this request.
        eng = self.index.engine
        if self._serve_eps is not None and eng._index_eps != self._serve_eps:
            eng._build_index(self._serve_eps)
            stats.index_rebuilds += 1
        stats.num_requests = 1
        stats.num_traces = self._trace_count - traces_before
        self.total.accumulate(stats)
        return stats

    def _eps_cap(self, q: np.ndarray) -> float:
        """Diagonal of the joint query/data bounding box: a provable upper
        bound on any query-to-data distance (small fp slack added).

        ``index.bounds()`` is in the reordered frame, so the queries are
        transformed before the per-dim extents combine (the diagonal length
        itself is permutation-invariant)."""
        lo_d, hi_d = self.index.bounds()
        q64 = self.index.transform_queries(q).astype(np.float64)
        lo = np.minimum(lo_d, q64.min(axis=0))
        hi = np.maximum(hi_d, q64.max(axis=0))
        diag = float(np.sqrt(((hi - lo) ** 2).sum()))
        return diag * (1.0 + 2**-10) + 1e-6

    # -- requests ----------------------------------------------------------

    def range_count(
        self, q: np.ndarray, eps: Optional[float] = None
    ) -> RangeCountResult:
        """Per-query counts of indexed points within eps (self not excluded)."""
        q = np.ascontiguousarray(np.asarray(q, dtype=np.float32))
        eps = self.index.config.eps if eps is None else float(eps)
        stats = ServiceStats(num_queries=q.shape[0], eps=eps)
        traces0 = self._trace_count
        counts = np.zeros(q.shape[0], np.int64)
        tab = self._prepare(q, eps, stats) if q.shape[0] else None
        if tab is not None:
            counts = self._run_counts(tab, eps, stats)
        stats.num_results = int(counts.sum())
        return RangeCountResult(counts=counts, stats=self._finish(stats, traces0))

    def range_pairs(
        self, q: np.ndarray, eps: Optional[float] = None
    ) -> RangePairsResult:
        """All (query row, data id) pairs within eps, lexsorted.

        Runs the count program first (reusing the same plan tables), so the
        pairs buffer is sized to the exact result and never overflows.
        """
        q = np.ascontiguousarray(np.asarray(q, dtype=np.float32))
        eps = self.index.config.eps if eps is None else float(eps)
        stats = ServiceStats(num_queries=q.shape[0], eps=eps)
        traces0 = self._trace_count
        counts = np.zeros(q.shape[0], np.int64)
        pairs = np.zeros((0, 2), np.int32)
        tab = self._prepare(q, eps, stats) if q.shape[0] else None
        if tab is not None:
            counts = self._run_counts(tab, eps, stats)
            total = int(counts.sum())
            if total:
                pairs = self._run_pairs(tab, eps, total, stats)
        stats.num_results = int(counts.sum())
        return RangePairsResult(
            pairs=pairs, counts=counts, stats=self._finish(stats, traces0)
        )

    def knn(
        self, q: np.ndarray, k: int, eps0: Optional[float] = None
    ) -> KnnResult:
        """k nearest indexed points per query, exact, ties broken by data id.

        Adaptive eps expansion (Hybrid KNN-Join, arXiv:1810.04758, on the
        range-query index of arXiv:1803.04120): run the count program at a
        starting radius (``eps0``, default the index build radius), double
        it until every query holds >= min(k, |D|) candidates (capped at the
        joint bounding-box diagonal, where every point is a candidate), then
        materialize pairs once at the final radius and take the exact top-k
        by (distance, data id) per query.
        """
        q = np.ascontiguousarray(np.asarray(q, dtype=np.float32))
        nq = q.shape[0]
        k = int(k)
        if k < 0:
            raise ValueError(f"k must be >= 0, got {k}")
        n_d = self.index.num_points
        stats = ServiceStats(num_queries=nq)
        traces0 = self._trace_count
        indices = np.full((nq, k), -1, np.int64)
        distances = np.full((nq, k), np.inf, np.float64)
        counts = np.zeros(nq, np.int64)
        if nq == 0 or n_d == 0 or k == 0:
            return KnnResult(
                indices=indices, distances=distances, counts=counts,
                stats=self._finish(stats, traces0),
            )

        k_eff = min(k, n_d)
        eps_cap = self._eps_cap(q)
        eps = self.index.config.eps if eps0 is None else float(eps0)
        if eps <= 0.0:  # an eps==0 index would never grow by doubling
            eps = eps_cap / 1024.0
        eps = min(eps, eps_cap)
        while True:
            tab = self._prepare(q, eps, stats)
            counts = self._run_counts(tab, eps, stats)
            stats.eps_rounds += 1
            if (counts >= k_eff).all() or eps >= eps_cap:
                break
            eps = min(2.0 * eps, eps_cap)
        stats.eps = eps

        pairs = self._run_pairs(tab, eps, int(counts.sum()), stats)
        indices, distances = self._topk_from_pairs(q, pairs, k, nq)
        stats.num_results = int((indices >= 0).sum())
        return KnnResult(
            indices=indices, distances=distances, counts=counts,
            stats=self._finish(stats, traces0),
        )

    def _topk_from_pairs(
        self, q: np.ndarray, pairs: np.ndarray, k: int, nq: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact per-query top-k over the candidate pairs, float64 distances."""
        indices = np.full((nq, k), -1, np.int64)
        distances = np.full((nq, k), np.inf, np.float64)
        if pairs.shape[0] == 0:
            return indices, distances
        qi = pairs[:, 0].astype(np.int64)
        di = pairs[:, 1].astype(np.int64)
        diffs = q[qi].astype(np.float64) - self.index.points[di].astype(np.float64)
        dist = np.sqrt(np.einsum("ij,ij->i", diffs, diffs))
        srt = np.lexsort((di, dist, qi))   # by query, then distance, then id
        qi, di, dist = qi[srt], di[srt], dist[srt]
        seg = np.concatenate([[0], np.cumsum(np.bincount(qi, minlength=nq))])
        rank = np.arange(qi.shape[0], dtype=np.int64) - seg[qi]
        sel = rank < k
        indices[qi[sel], rank[sel]] = di[sel]
        distances[qi[sel], rank[sel]] = dist[sel]
        return indices, distances
