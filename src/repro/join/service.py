"""Batched online query serving over a ``SimilarityIndex`` (DESIGN.md #8, #10).

``QueryService`` answers three request kinds against one resident index:

  ``range_count(q, eps)``  per-query counts of live points within eps;
  ``range_pairs(q, eps)``  the materialized (query row, global id) pairs;
  ``knn(q, k)``            k nearest live points per query, found by
                           adaptive eps expansion on the count program
                           (double the radius until every query holds >= k
                           candidates, then one pairs pass + exact top-k).

Epoch pinning (DESIGN.md #10): every request pins an ``IndexView`` at
entry -- the engine's frozen ``GridSnapshot`` plus the churn state (delta
buffer, tombstones) of that instant -- and serves entirely from it, so a
concurrent ``compact()`` swap lands without tearing a request and without
touching its answers.  A radius above the pinned snapshot's build radius
serves from a TEMPORARY rebuilt snapshot (``GridSnapshot.rebuilt``,
counted in ``stats.index_rebuilds``) that is dropped at request end; the
resident snapshot -- and every warm executable keyed to its shape buckets
-- is never disturbed.  This replaces the old grid-restore special case.

Mutable-index epilogue: the snapshot pass answers for the snapshot's
points; a small dense bipartite pass (one jitted program over pow2-padded
delta/tombstone tables) then SUBTRACTS tombstoned matches and ADDS
delta-buffer matches, so counts, pairs, and kNN always reflect the live
set = snapshot 'minus' tombstones 'plus' inserts.  Pair results carry GLOBAL
ids (stable across compactions).

Compilation discipline -- the property that makes this a *service* rather
than a loop of one-shot joins: request batches are padded to power-of-two
shape buckets (``SelfJoinEngine.prepare_query(pad_queries_to=...)``), the
snapshot's data-side tables are padded to its own pow2 row buckets, eps is
always a traced scalar, and the chunk programs are jitted once per service
with a host-side trace counter in the traced body, so an arbitrary request
stream compiles at most one count and one pairs executable per bucket --
and a snapshot swap of unchanged buckets adds ZERO traces.
``ServiceStats.num_traces`` reports it per request and
``QueryService.total`` accumulates it across the stream -- the serving
analogue of the fused ring's ``fused_traces == 1`` contract.

Execution tiers (DESIGN.md #9): every request batch flows through the
engine's cost-model dispatch (``SelfJoinConfig.execution``), so a
high-dimensional stream where the grid has lost its filtering power is
served by the dense matmul tier.  The tier is part of each executable's
static trace key (``backend``/``shortc``), so a mixed stream straddling the
dispatch boundary compiles at most one count and one pairs executable per
shape bucket *per tier*; ``ServiceStats`` records the tier served and the
cost model's two estimates.

kNN tie-breaking is deterministic: neighbours sort by (distance, global
id), and queries with fewer than k reachable neighbours (k >= live count)
pad with id -1 / distance +inf.  The eps expansion is capped at the
diagonal of the joint query/live-data bounding box, which provably
contains every candidate, so termination never depends on the data
distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    QueryPlanTables,
    count_chunk_step,
    pairs_chunk_step,
)
from repro.core.grid import pad_axis0
from repro.join.index import IndexView, SimilarityIndex
from repro import obs
from repro.kernels import ops

_MAX_HITCAP_RETRIES = 8


@dataclasses.dataclass
class ServiceStats:
    """Per-request (and, via ``QueryService.total``, cumulative) counters."""

    num_requests: int = 0        # requests served (1 per response object)
    num_queries: int = 0         # query rows in the batch
    bucket: int = 0              # padded slot count the batch was served in
    eps: float = 0.0             # final radius evaluated
    eps_rounds: int = 0          # kNN eps-expansion count passes (1 = no growth)
    num_traces: int = 0          # NEW chunk-program traces this request caused
    num_device_dispatches: int = 0  # chunk-program launches
    num_candidates: int = 0      # point comparisons the chosen tier evaluated
    num_results: int = 0         # neighbours counted / pairs returned
    index_rebuilds: int = 0      # temporary snapshots built for over-radius requests
    epoch: int = 0               # compaction epoch the request pinned
    delta_size: int = 0          # live delta-buffer points joined alongside
    tombstone_count: int = 0     # tombstoned points masked at the epilogue
    execution: str = ""          # tier that served this request ("mixed" across
                                 # requests/eps rounds that disagree)
    cost_indexed: float = 0.0    # summed cost-model indexed-tier estimates
    cost_dense: float = 0.0      # summed cost-model dense-tier estimates

    def record_tier(self, execution: str, ci: float, cd: float) -> None:
        if self.execution and self.execution != execution:
            self.execution = "mixed"
        else:
            self.execution = execution
        self.cost_indexed += ci
        self.cost_dense += cd

    def accumulate(self, other: "ServiceStats") -> None:
        self.num_requests += other.num_requests
        self.num_queries += other.num_queries
        self.bucket = max(self.bucket, other.bucket)
        self.eps = max(self.eps, other.eps)
        self.eps_rounds += other.eps_rounds
        self.num_traces += other.num_traces
        self.num_device_dispatches += other.num_device_dispatches
        self.num_candidates += other.num_candidates
        self.num_results += other.num_results
        self.index_rebuilds += other.index_rebuilds
        # high-water marks of the churn state seen across the stream
        self.epoch = max(self.epoch, other.epoch)
        self.delta_size = max(self.delta_size, other.delta_size)
        self.tombstone_count = max(self.tombstone_count, other.tombstone_count)
        if other.execution:
            self.record_tier(
                other.execution, other.cost_indexed, other.cost_dense
            )


@dataclasses.dataclass
class RangeCountResult:
    counts: np.ndarray           # (nq,) int64, batch row order
    stats: ServiceStats


@dataclasses.dataclass
class RangePairsResult:
    pairs: np.ndarray            # (R, 2) int64 (query row, global id), lexsorted
    counts: np.ndarray           # (nq,) int64
    stats: ServiceStats


@dataclasses.dataclass
class KnnResult:
    indices: np.ndarray          # (nq, k) int64 global ids, -1 where < k exist
    distances: np.ndarray        # (nq, k) float64, +inf where < k exist
    counts: np.ndarray           # (nq,) int64 candidates at the final radius
    stats: ServiceStats


class QueryService:
    """Batched range + kNN serving over one ``SimilarityIndex``.

    Queries are given in ORIGINAL coordinates; the service permutes them
    with the index's persisted REORDER permutation where the grid needs it.
    Each request pins the index epoch at entry and serves from that pinned
    view; inserts, deletes and compactions land between requests without
    retracing anything warm.
    """

    def __init__(self, index: SimilarityIndex, *, min_bucket: int = 16):
        if min_bucket < 1:
            raise ValueError("min_bucket must be >= 1")
        self.index = index
        self.min_bucket = int(min_bucket)
        self.total = ServiceStats()
        self.buckets_used: Set[int] = set()
        self._trace_count = 0

        cfg = index.config
        eng = index.engine.engine
        self._count_chunk = eng.count_chunk
        self._pairs_chunk = eng.pairs_chunk

        # The service's three executables, jitted once per service instance.
        # The bodies run ONLY when XLA traces a new (bucket) shape, so the
        # counter increments measure exactly the compile-reuse contract.
        # ``backend``/``shortc`` are static: a stream that straddles the
        # dense/indexed dispatch boundary compiles at most one executable
        # per shape bucket PER TIER (the tile-table shapes differ between
        # tiers anyway, so the tier is already part of the trace key).
        def _count_step(
            counts, tiles, tile_len, tile_start, pa, pb, real, eps,
            *, backend, shortc,
        ):
            self._trace_count += 1
            # the "trace" obs category fires exactly when _trace_count
            # increments, so trace-span count == ServiceStats.num_traces
            obs.event("service.trace", "trace", program="count")
            counts, _ = count_chunk_step(
                counts, jnp.zeros((), jnp.int32),
                tiles, tile_len, tile_start, pa, pb, real, eps,
                dim_block=cfg.dim_block, shortc=shortc,
                backend=backend, interpret=eng.interpret,
            )
            return counts

        def _pairs_step(
            buf, offset, max_hits, tiles, tile_len, tile_start, order,
            pa, pb, real, eps, *, hit_cap, backend,
        ):
            self._trace_count += 1
            obs.event("service.trace", "trace", program="pairs")
            return pairs_chunk_step(
                buf, offset, max_hits, tiles, tile_len, tile_start, order,
                pa, pb, real, eps,
                hit_cap=hit_cap, dim_block=cfg.dim_block,
                backend=backend, interpret=eng.interpret,
            )

        # the delta/tombstone epilogue: one dense bipartite membership pass
        # of the (pow2-padded) query bucket against a (pow2-padded) aux
        # table, plain fp32 difference-square distances (exact on quantized
        # coords alongside the engine's matmul identity, DESIGN.md #6).
        # Rows past ``real`` are padding and masked out.
        def _aux_step(q, pts, real, eps):
            self._trace_count += 1
            obs.event("service.trace", "trace", program="aux")
            d2 = ((q[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
            valid = jnp.arange(pts.shape[0], dtype=jnp.int32) < real
            return (d2 <= eps * eps) & valid[None, :]

        self._count_step = jax.jit(
            _count_step, static_argnames=("backend", "shortc")
        )
        self._pairs_step = jax.jit(
            _pairs_step, static_argnames=("hit_cap", "backend")
        )
        self._aux_step = jax.jit(_aux_step)

    # -- bucketing ---------------------------------------------------------

    def bucket_size(self, nq: int) -> int:
        """Power-of-two slot count (>= min_bucket) the batch is padded to."""
        return 1 << (max(int(nq), self.min_bucket) - 1).bit_length()

    # -- internal execution ------------------------------------------------

    def _pin(self, stats: ServiceStats) -> IndexView:
        """Pin the index epoch for one request and record its churn state."""
        with obs.span("service.pin", "service"):
            view = self.index.view()
        stats.epoch = view.epoch
        stats.delta_size = view.delta_size
        stats.tombstone_count = view.tombstone_count
        return view

    def _prepare(
        self, q: np.ndarray, eps: float, view: IndexView, stats: ServiceStats
    ) -> Optional[QueryPlanTables]:
        """Plan tables against the PINNED snapshot (never the live engine).

        An eps above the pinned build radius gets a temporary rebuilt
        snapshot -- same permutation, buckets floored at the pinned one's --
        which this request alone serves from and then drops.
        """
        bucket = self.bucket_size(q.shape[0])
        snap = view.snapshot
        if (
            snap.num_points
            and snap.index_eps is not None
            and eps > snap.index_eps
        ):
            snap = snap.rebuilt(eps)
            stats.index_rebuilds += 1
        tab = self.index.engine.prepare_query(
            q, eps, pad_queries_to=bucket, snapshot=snap
        )
        stats.bucket = bucket
        self.buckets_used.add(bucket)
        if tab is not None:
            stats.record_tier(tab.execution, tab.cost_indexed, tab.cost_dense)
        return tab

    def _tier_kwargs(self, tab: QueryPlanTables) -> dict:
        cfg = self.index.config
        return {
            "backend": ops.backend_name(tab.execution, cfg.use_pallas),
            "shortc": cfg.shortc and tab.execution == "indexed",
        }

    def _run_counts(
        self, tab: QueryPlanTables, eps: float, stats: ServiceStats
    ) -> np.ndarray:
        tier = self._tier_kwargs(tab)
        counts_sorted = jnp.zeros(tab.n_slots, jnp.int32)
        for pa, pb, real in tab.chunks(self._count_chunk):
            with obs.span(
                "service.count.chunk", "dispatch", bucket=tab.n_slots
            ):
                counts_sorted = self._count_step(
                    counts_sorted, tab.tiles, tab.tile_len, tab.tile_start,
                    pa, pb, real, jnp.float32(eps), **tier,
                )
            stats.num_device_dispatches += 1
        stats.num_candidates += tab.num_candidates
        cs = np.asarray(counts_sorted)
        counts = np.zeros(tab.nq, np.int64)
        counts[tab.qplan.q_order] = cs[: tab.nq]
        return counts

    def _run_pairs(
        self, tab: QueryPlanTables, eps: float, total: int, stats: ServiceStats
    ) -> np.ndarray:
        """One pairs pass sized exactly from the known count total."""
        t = int(self.index.config.tile_size)
        backend = self._tier_kwargs(tab)["backend"]
        flat_per_chunk = self._pairs_chunk * t * t
        hit_cap = min(flat_per_chunk, 4096)
        cap = 1 << (max(int(total), 1) - 1).bit_length()  # pow2: bounded trace keys
        for _ in range(_MAX_HITCAP_RETRIES + 1):
            buf = jnp.zeros((cap + hit_cap, 2), jnp.int32)
            offset = jnp.zeros((), jnp.int32)
            max_hits = jnp.zeros((), jnp.int32)
            for pa, pb, real in tab.chunks(self._pairs_chunk):
                with obs.span(
                    "service.pairs.chunk", "dispatch", bucket=tab.n_slots
                ):
                    buf, offset, max_hits = self._pairs_step(
                        buf, offset, max_hits,
                        tab.tiles, tab.tile_len, tab.tile_start, tab.order,
                        pa, pb, real, jnp.float32(eps), hit_cap=hit_cap,
                        backend=backend,
                    )
                stats.num_device_dispatches += 1
            if int(max_hits) <= hit_cap:
                break
            # a single chunk outgrew the rank window: widen to the observed
            # maximum (pow2 so the retry shapes stay bounded) and redo
            obs.event(
                "service.pairs.retry", "retry", kind="hit_cap",
                max_hits=int(max_hits), hit_cap=hit_cap,
            )
            hit_cap = min(
                flat_per_chunk, 1 << (int(max_hits) - 1).bit_length()
            )
        num = int(offset)
        if num != total:
            raise RuntimeError(
                f"pairs pass found {num} pairs but the count pass said {total}"
            )
        return np.asarray(buf[:num])

    def _aux_mask(
        self,
        q: np.ndarray,
        pts_dev: Optional[jnp.ndarray],
        m: int,
        eps: float,
        stats: ServiceStats,
    ) -> Optional[np.ndarray]:
        """(nq, m_padded) within-eps membership of q against an aux table."""
        if pts_dev is None or q.shape[0] == 0:
            return None
        qb = pad_axis0(q, self.bucket_size(q.shape[0]))
        with obs.span("service.aux", "dispatch", m=m):
            mask = self._aux_step(
                jnp.asarray(qb), pts_dev, jnp.int32(m), jnp.float32(eps)
            )
        stats.num_device_dispatches += 1
        stats.num_candidates += q.shape[0] * m
        return np.asarray(mask)[: q.shape[0]]

    def _query_pass(
        self, q: np.ndarray, eps: float, view: IndexView, stats: ServiceStats
    ):
        """Snapshot counts + churn epilogue at one radius.

        Returns ``(tab, snap_counts, counts, delta_mask)``: the plan tables
        (None for an empty snapshot), the UNCORRECTED snapshot counts (they
        size the pairs pass), the live-set counts, and the delta membership
        mask (None when the delta is empty).
        """
        with obs.span(
            "service.eps_round", "service", eps=eps, nq=int(q.shape[0])
        ):
            tab = self._prepare(q, eps, view, stats)
            if tab is not None:
                snap_counts = self._run_counts(tab, eps, stats)
            else:
                snap_counts = np.zeros(q.shape[0], np.int64)
            counts = snap_counts.copy()
            dead_mask = self._aux_mask(
                q, view.dead_dev, view.tombstone_count, eps, stats
            )
            if dead_mask is not None:
                counts -= dead_mask.sum(axis=1)
            delta_mask = self._aux_mask(
                q, view.delta_dev, view.delta_size, eps, stats
            )
            if delta_mask is not None:
                counts += delta_mask.sum(axis=1)
            return tab, snap_counts, counts, delta_mask

    def _global_pairs(
        self,
        eps: float,
        tab: Optional[QueryPlanTables],
        view: IndexView,
        snap_counts: np.ndarray,
        delta_mask: Optional[np.ndarray],
        stats: ServiceStats,
    ) -> np.ndarray:
        """Materialized (query row, GLOBAL id) pairs of the live set."""
        with obs.span("service.epilogue", "service", eps=eps):
            return self._global_pairs_impl(
                eps, tab, view, snap_counts, delta_mask, stats
            )

    def _global_pairs_impl(
        self,
        eps: float,
        tab: Optional[QueryPlanTables],
        view: IndexView,
        snap_counts: np.ndarray,
        delta_mask: Optional[np.ndarray],
        stats: ServiceStats,
    ) -> np.ndarray:
        parts = []
        snap_total = int(snap_counts.sum())
        if tab is not None and snap_total:
            sp = self._run_pairs(tab, eps, snap_total, stats)
            if view.tombstone_count:
                sp = sp[~np.isin(sp[:, 1], view.dead_rows)]
            if sp.shape[0]:
                parts.append(np.column_stack(
                    [sp[:, 0].astype(np.int64), view.snap_ids[sp[:, 1]]]
                ))
        if delta_mask is not None:
            qr, j = np.nonzero(delta_mask)
            if qr.size:
                parts.append(np.column_stack(
                    [qr.astype(np.int64), view.delta_ids[j]]
                ))
        if not parts:
            return np.zeros((0, 2), np.int64)
        pairs = np.concatenate(parts)
        srt = np.lexsort((pairs[:, 1], pairs[:, 0]))
        return np.ascontiguousarray(pairs[srt])

    def _finish(
        self, stats: ServiceStats, traces_before: int, kind: str
    ) -> ServiceStats:
        stats.num_requests = 1
        stats.num_traces = self._trace_count - traces_before
        self.total.accumulate(stats)
        obs.event("service.unpin", "service", epoch=stats.epoch)
        obs.mirror_service_stats(stats, kind=kind)
        obs.request_log(kind, stats)
        return stats

    def _eps_cap(self, q: np.ndarray, view: IndexView) -> float:
        """Diagonal of the joint query/live-data bounding box: a provable
        upper bound on any query-to-live-point distance (small fp slack
        added).  Both sides are in the ORIGINAL frame (the diagonal length
        is permutation-invariant), and the data side is the pinned view's
        LIVE bounds -- so the cap, and with it the kNN eps trajectory, is
        identical before and after a compact of the same live set."""
        lo_d, hi_d = view.live_bounds
        q64 = q.astype(np.float64)
        lo = np.minimum(lo_d, q64.min(axis=0))
        hi = np.maximum(hi_d, q64.max(axis=0))
        diag = float(np.sqrt(((hi - lo) ** 2).sum()))
        return diag * (1.0 + 2**-10) + 1e-6

    # -- requests ----------------------------------------------------------

    def range_count(
        self, q: np.ndarray, eps: Optional[float] = None
    ) -> RangeCountResult:
        """Per-query counts of live points within eps (self not excluded)."""
        q = np.ascontiguousarray(np.asarray(q, dtype=np.float32))
        eps = self.index.config.eps if eps is None else float(eps)
        stats = ServiceStats(num_queries=q.shape[0], eps=eps)
        traces0 = self._trace_count
        with obs.span(
            "service.request", "request",
            kind="range_count", nq=int(q.shape[0]), eps=eps,
        ):
            view = self._pin(stats)
            counts = np.zeros(q.shape[0], np.int64)
            if q.shape[0]:
                _, _, counts, _ = self._query_pass(q, eps, view, stats)
            stats.num_results = int(counts.sum())
            return RangeCountResult(
                counts=counts,
                stats=self._finish(stats, traces0, "range_count"),
            )

    def range_pairs(
        self, q: np.ndarray, eps: Optional[float] = None
    ) -> RangePairsResult:
        """All (query row, global id) pairs within eps, lexsorted.

        Runs the count program first (reusing the same plan tables), so the
        pairs buffer is sized to the exact snapshot result and never
        overflows; tombstoned rows are filtered and delta matches merged
        afterwards.
        """
        q = np.ascontiguousarray(np.asarray(q, dtype=np.float32))
        eps = self.index.config.eps if eps is None else float(eps)
        stats = ServiceStats(num_queries=q.shape[0], eps=eps)
        traces0 = self._trace_count
        with obs.span(
            "service.request", "request",
            kind="range_pairs", nq=int(q.shape[0]), eps=eps,
        ):
            view = self._pin(stats)
            counts = np.zeros(q.shape[0], np.int64)
            pairs = np.zeros((0, 2), np.int64)
            if q.shape[0]:
                tab, snap_counts, counts, delta_mask = self._query_pass(
                    q, eps, view, stats
                )
                pairs = self._global_pairs(
                    eps, tab, view, snap_counts, delta_mask, stats
                )
            stats.num_results = int(counts.sum())
            return RangePairsResult(
                pairs=pairs, counts=counts,
                stats=self._finish(stats, traces0, "range_pairs"),
            )

    def knn(
        self, q: np.ndarray, k: int, eps0: Optional[float] = None
    ) -> KnnResult:
        """k nearest live points per query, exact, ties broken by global id.

        Adaptive eps expansion (Hybrid KNN-Join, arXiv:1810.04758, on the
        range-query index of arXiv:1803.04120): run the count program at a
        starting radius (``eps0``, default the index build radius), double
        it until every query holds >= min(k, live) candidates (capped at
        the joint bounding-box diagonal, where every point is a candidate),
        then materialize pairs once at the final radius and take the exact
        top-k by (distance, global id) per query.
        """
        q = np.ascontiguousarray(np.asarray(q, dtype=np.float32))
        nq = q.shape[0]
        k = int(k)
        if k < 0:
            raise ValueError(f"k must be >= 0, got {k}")
        stats = ServiceStats(num_queries=nq)
        traces0 = self._trace_count
        with obs.span(
            "service.request", "request", kind="knn", nq=nq, k=k,
        ):
            view = self._pin(stats)
            indices = np.full((nq, k), -1, np.int64)
            distances = np.full((nq, k), np.inf, np.float64)
            counts = np.zeros(nq, np.int64)
            if nq == 0 or view.live_count == 0 or k == 0:
                return KnnResult(
                    indices=indices, distances=distances, counts=counts,
                    stats=self._finish(stats, traces0, "knn"),
                )

            k_eff = min(k, view.live_count)
            eps_cap = self._eps_cap(q, view)
            eps = self.index.config.eps if eps0 is None else float(eps0)
            if eps <= 0.0:  # an eps==0 index would never grow by doubling
                eps = eps_cap / 1024.0
            eps = min(eps, eps_cap)
            while True:
                tab, snap_counts, counts, delta_mask = self._query_pass(
                    q, eps, view, stats
                )
                stats.eps_rounds += 1
                if (counts >= k_eff).all() or eps >= eps_cap:
                    break
                eps = min(2.0 * eps, eps_cap)
            stats.eps = eps

            pairs = self._global_pairs(
                eps, tab, view, snap_counts, delta_mask, stats
            )
            indices, distances = self._topk_from_pairs(q, pairs, k, nq)
            stats.num_results = int((indices >= 0).sum())
            return KnnResult(
                indices=indices, distances=distances, counts=counts,
                stats=self._finish(stats, traces0, "knn"),
            )

    def _topk_from_pairs(
        self, q: np.ndarray, pairs: np.ndarray, k: int, nq: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact per-query top-k over the candidate pairs, float64 distances."""
        indices = np.full((nq, k), -1, np.int64)
        distances = np.full((nq, k), np.inf, np.float64)
        if pairs.shape[0] == 0:
            return indices, distances
        qi = pairs[:, 0].astype(np.int64)
        di = pairs[:, 1].astype(np.int64)
        diffs = q[qi].astype(np.float64) - self.index.coords_of(di).astype(
            np.float64
        )
        dist = np.sqrt(np.einsum("ij,ij->i", diffs, diffs))
        srt = np.lexsort((di, dist, qi))   # by query, then distance, then id
        qi, di, dist = qi[srt], di[srt], dist[srt]
        seg = np.concatenate([[0], np.cumsum(np.bincount(qi, minlength=nq))])
        rank = np.arange(qi.shape[0], dtype=np.int64) - seg[qi]
        sel = rank < k
        indices[qi[sel], rank[sel]] = di[sel]
        distances[qi[sel], rank[sel]] = dist[sel]
        return indices, distances
