"""The language model: layer groups scanned over stacked parameters.

Each layer group is ``(pattern, repeat)``; parameters of each pattern
position are stacked along a leading repeat axis and the group is a single
``lax.scan`` -- a 64-layer model lowers to one block body per pattern
position, keeping compile time and HLO size flat in depth (DESIGN.md #2).

Entry points:
  init_params / abstract_params
  forward_train(params, batch)           -> (loss, logits)
  prefill(params, tokens, cache_len)     -> (last-token logits, cache)
  decode_step(params, cache, token, pos) -> (logits, new cache)
Encoder-decoder (seamless) and VLM (llama-3.2-vision) share these entry
points; their extra inputs (frames / patch embeddings) ride in the batch
dict, produced in dry-runs by ``input_specs()`` stubs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks as B
from repro.models import layers as L
from repro.models.config import BlockCfg, ModelConfig


def _adt(cfg):
    return jnp.dtype(cfg.activation_dtype)


def _pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------- init -----


def _group_init(key, cfg, pattern, repeat):
    """Stacked params: per pattern position, a pytree with leading (repeat,)."""
    out = []
    for i, blk in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), repeat)
        out.append(jax.vmap(lambda k, b=blk: B.block_init(k, cfg, b))(keys))
    return out


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = _pdt(cfg)
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "groups": [
            _group_init(jax.random.fold_in(ks[1], gi), cfg, pattern, repeat)
            for gi, (pattern, repeat) in enumerate(cfg.groups)
        ],
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab, dtype)
    if cfg.encoder_groups is not None:
        params["enc_proj"] = L.dense_init(ks[3], cfg.enc_input_dim, cfg.d_model, dtype)
        params["enc_groups"] = [
            _group_init(jax.random.fold_in(ks[4], gi), cfg, pattern, repeat)
            for gi, (pattern, repeat) in enumerate(cfg.encoder_groups)
        ]
        params["enc_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
    if cfg.vision_tokens:
        params["vision_proj"] = L.dense_init(ks[5], cfg.vision_dim, cfg.d_model, dtype)
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree -- no allocation (used by the dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0)
    )


# ------------------------------------------------------------- forward -----


def _run_groups(groups_params, x, positions, cfg, group_cfgs, *, memory=None,
                want_cache=False, cache_len=0):
    """Apply all layer groups; optionally collect decode caches."""
    caches = []
    for gp, (pattern, repeat) in zip(groups_params, group_cfgs):

        per_block = cfg.remat and cfg.remat_mode in ("block", "double")

        def body(carry, xs, pattern=pattern):
            h = carry
            new_caches = []
            for i, blk in enumerate(pattern):

                def one(p_i, h_i, blk=blk):
                    return B.block_seq(
                        p_i, h_i, positions, cfg, blk,
                        memory=memory, want_cache=want_cache,
                        cache_len=cache_len,
                    )

                fn = jax.checkpoint(one) if per_block else one
                h, c = fn(xs[i], h)
                new_caches.append(c)
            return h, tuple(new_caches) if want_cache else None

        outer = cfg.remat and cfg.remat_mode in ("pattern", "double")
        body_fn = jax.checkpoint(body) if outer else body
        x, group_cache = jax.lax.scan(body_fn, x, tuple(gp))
        caches.append(group_cache)
    return x, caches


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.dense(params["unembed"], x, jnp.float32)
    return L.softcap(logits, cfg.logit_softcap)


def _embed_tokens(params, cfg, tokens):
    x = L.embed(params["embed"], tokens, _adt(cfg))
    return x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)


def _encode(params, cfg, frames):
    """Encoder stack (seamless): frames (B, Sa, enc_input_dim) -> memory."""
    x = L.dense(params["enc_proj"], frames.astype(_adt(cfg)))
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _ = _run_groups(
        params["enc_groups"], x, pos, cfg, cfg.encoder_groups
    )
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _memory(params, cfg, batch):
    if cfg.encoder_groups is not None:
        return _encode(params, cfg, batch["frames"])
    if cfg.vision_tokens:
        return L.dense(params["vision_proj"], batch["patches"].astype(_adt(cfg)))
    return None


def _backbone(params, batch, cfg):
    tokens = batch["tokens"]
    memory = _memory(params, cfg, batch)
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, _ = _run_groups(params["groups"], x, positions, cfg, cfg.groups, memory=memory)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward_train(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """batch: {"tokens": (B,S) int32, "labels": (B,S) int32, [frames|patches]}.

    Returns (mean CE loss, logits fp32).  Materializes logits -- use
    ``forward_loss`` in the training step (streaming CE, no logits).
    """
    x = _backbone(params, batch, cfg)
    logits = _logits(params, cfg, x)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, logits


def forward_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """Training loss via streaming (vocab-chunked) cross-entropy: the
    (B, S, vocab) logits are never materialized (65 GB/device at gemma3's
    train_4k shape otherwise -- see EXPERIMENTS.md #Perf iteration 1)."""
    x = _backbone(params, batch, cfg)
    if cfg.ce_chunk <= 0:
        loss, _ = forward_train(params, batch, cfg)  # pragma: no cover
        return loss
    if cfg.tie_embeddings:
        return L.blocked_cross_entropy(
            x, batch["labels"], table=params["embed"]["table"],
            chunk=cfg.ce_chunk, logit_softcap=cfg.logit_softcap,
        )
    return L.blocked_cross_entropy(
        x, batch["labels"], w=params["unembed"]["w"],
        bias=params["unembed"].get("b"),
        chunk=cfg.ce_chunk, logit_softcap=cfg.logit_softcap,
    )


def prefill(params, batch, cfg: ModelConfig, cache_len: int):
    """Run the context and build decode caches.

    Returns (last-position logits (B, vocab), caches, memory).
    """
    tokens = batch["tokens"]
    memory = _memory(params, cfg, batch)
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, caches = _run_groups(
        params["groups"], x, positions, cfg, cfg.groups,
        memory=memory, want_cache=True, cache_len=cache_len,
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x[:, -1])
    return logits, caches, memory


def init_caches(cfg: ModelConfig, batch: int, cache_len: int):
    """Zero caches matching prefill's structure (for dry-run decode)."""
    dtype = _adt(cfg)
    caches = []
    for pattern, repeat in cfg.groups:
        per_pos = []
        for blk in pattern:
            one = B.block_init_cache(cfg, blk, batch, cache_len, dtype)
            per_pos.append(
                jax.tree.map(lambda a: jnp.broadcast_to(a[None], (repeat,) + a.shape), one)
            )
        caches.append(tuple(per_pos))
    return caches


def decode_step(params, caches, token, pos, cfg: ModelConfig, *, memory=None):
    """token: (B,) int32; pos: scalar int32. Returns (logits, new caches)."""
    x = _embed_tokens(params, cfg, token[:, None])
    new_caches = []
    for gp, gc, (pattern, repeat) in zip(params["groups"], caches, cfg.groups):

        def body(carry, xs, pattern=pattern):
            h = carry
            p_slices, c_slices = xs
            outs = []
            for i, blk in enumerate(pattern):
                h, c = B.block_step(
                    p_slices[i], h, c_slices[i], pos, cfg, blk, memory=memory
                )
                outs.append(c)
            return h, tuple(outs)

        x, gc_new = jax.lax.scan(body, x, (tuple(gp), gc))
        new_caches.append(gc_new)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x[:, 0])
    return logits, new_caches


# ------------------------------------------------------------- counting ----


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    params = abstract_params(cfg)
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        moe_layers = sum(
            sum(1 for b in pattern if b.moe) * repeat for pattern, repeat in cfg.groups
        )
        per_expert = 3 * cfg.d_model * m.expert_ff
        total -= (m.num_experts - m.top_k) * per_expert * moe_layers
    return total
