"""Decoder/encoder block assembly from BlockCfg.

Every block kind exposes three entry points sharing one param pytree:
  init   -- parameters
  seq    -- full-sequence forward (train / prefill); optionally fills a cache
  step   -- single-token decode against the cache/state
Pre-norm residual structure throughout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import recurrent as R
from repro.models.config import BlockCfg, ModelConfig


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _ffn_init(key, cfg, blk, dtype):
    if blk.moe:
        return MOE.moe_init(key, cfg, dtype)
    return L.swiglu_init(key, cfg.d_model, cfg.d_ff, dtype)


def _ffn_apply(p, x, cfg, blk):
    if blk.moe:
        return MOE.moe_apply(p, x, cfg)
    return L.swiglu(p, x)


def block_init(key, cfg: ModelConfig, blk: BlockCfg):
    dtype = _dtype(cfg)
    d = cfg.d_model
    dims = A.AttnDims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {"ln1": L.rmsnorm_init(d, dtype)}
    if blk.kind == "attn":
        if cfg.mla is not None:
            p["attn"] = MLA.mla_init(k1, cfg, dtype)
        else:
            p["attn"] = A.attn_init(
                k1, d, dims, dtype, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm
            )
        if blk.cross_attn:
            p["lnx"] = L.rmsnorm_init(d, dtype)
            p["xattn"] = A.attn_init(k4, d, dims, dtype, qk_norm=cfg.qk_norm)
            p["xgate"] = jnp.zeros((1,), dtype)  # gated cross-attn (llama-vision)
        if blk.mlp:
            p["ln2"] = L.rmsnorm_init(d, dtype)
            p["ffn"] = _ffn_init(k2, cfg, blk, dtype)
    elif blk.kind == "recurrent":
        p["rec"] = R.recurrent_block_init(k1, d, cfg.d_rnn, cfg.conv_width, dtype)
        if blk.mlp:
            p["ln2"] = L.rmsnorm_init(d, dtype)
            p["ffn"] = _ffn_init(k2, cfg, blk, dtype)
    elif blk.kind == "mlstm":
        p["cell"] = R.mlstm_init(k1, d, cfg.num_heads, 2 * d, dtype)
    elif blk.kind == "slstm":
        p["cell"] = R.slstm_init(k1, d, cfg.num_heads, dtype)
    else:
        raise ValueError(f"unknown block kind {blk.kind}")
    return p


# --------------------------------------------------------- sequence form ---


def block_seq(p, x, positions, cfg, blk, *, memory=None, want_cache=False,
              cache_len=0):
    """Full-sequence block. Returns (x, cache_or_state or None)."""
    cache = None
    if blk.kind == "attn":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cfg.mla is not None:
            # absorbed form pays 1.8x score FLOPs to kill per-head K/V
            # traffic -- a win for prefill (memory-bound, no backward) but a
            # regression for training (EXPERIMENTS.md #Perf cell B iter 3):
            # gate it on the prefill path (want_cache)
            use_absorbed = cfg.mla_absorbed and want_cache
            mla_fn = (
                MLA.mla_attention_absorbed if use_absorbed
                else MLA.mla_attention
            )
            y = mla_fn(p["attn"], h, positions, cfg, blk)
            if want_cache:
                cache = _mla_prefill_cache(p["attn"], h, positions, cfg, cache_len)
        else:
            if want_cache:
                y, (k, v) = A.attention(
                    p["attn"], h, positions, cfg, blk,
                    causal=not blk.bidirectional, return_kv=True,
                )
                cache = _kv_prefill_cache(k, v, positions, cfg, blk, cache_len)
            else:
                y = A.attention(
                    p["attn"], h, positions, cfg, blk,
                    causal=not blk.bidirectional,
                )
        x = x + y
        if blk.cross_attn and memory is not None:
            hx = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
            gx = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
            x = x + gx * A.attention(p["xattn"], hx, positions, cfg, blk, memory=memory)
        if blk.mlp:
            x = x + _ffn_apply(p["ffn"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg, blk)
    elif blk.kind == "recurrent":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, state = R.recurrent_block_seq(p["rec"], h)
        x = x + y
        if blk.mlp:
            x = x + _ffn_apply(p["ffn"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg, blk)
        cache = state if want_cache else None
    elif blk.kind == "mlstm":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, state = R.mlstm_seq(p["cell"], h, cfg.num_heads)
        x = x + y
        cache = state if want_cache else None
    elif blk.kind == "slstm":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, state = R.slstm_seq(p["cell"], h, cfg.num_heads)
        x = x + y
        cache = state if want_cache else None
    return x, cache


def _kv_prefill_cache(k, v, positions, cfg, blk, cache_len):
    """Place prefill K/V into a decode cache (ring layout for local attn)."""
    b, s = k.shape[0], k.shape[1]
    cache = A.init_cache(cfg, blk, b, cache_len, k.dtype)
    slots = cache["k"].shape[1]
    if s >= slots:  # keep the last `slots` positions (ring)
        sel = jnp.arange(s - slots, s)
        kk, vv, pp = k[:, sel], v[:, sel], positions[sel]
        idx = pp % slots
        cache["k"] = cache["k"].at[:, idx].set(kk)
        cache["v"] = cache["v"].at[:, idx].set(vv)
        cache["pos"] = cache["pos"].at[idx].set(pp)
    else:
        idx = positions % slots
        cache["k"] = cache["k"].at[:, idx].set(k)
        cache["v"] = cache["v"].at[:, idx].set(v)
        cache["pos"] = cache["pos"].at[idx].set(positions)
    return cache


def _mla_prefill_cache(p_attn, h, positions, cfg, cache_len):
    m = cfg.mla
    b, s, _ = h.shape
    cache = MLA.mla_init_cache(cfg, b, cache_len, h.dtype)
    ckv = L.rmsnorm(p_attn["kvnorm"], L.dense(p_attn["wdkv"], h), cfg.norm_eps)
    kr = L.dense(p_attn["wkr"], h)
    cos, sin = L.rope_cos_sin(positions, m.qk_rope_head_dim, 10_000.0)
    kr = L.apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0]
    cache["ckv"] = cache["ckv"].at[:, positions].set(ckv.astype(cache["ckv"].dtype))
    cache["kr"] = cache["kr"].at[:, positions].set(kr.astype(cache["kr"].dtype))
    cache["pos"] = cache["pos"].at[positions].set(positions)
    return cache


# ------------------------------------------------------------ step form ----


def block_step(p, x, cache, pos, cfg, blk, *, memory=None):
    """One-token decode. x: (B,1,D). Returns (x, new_cache)."""
    if blk.kind == "attn":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cfg.mla is not None:
            y, cache = MLA.mla_decode(p["attn"], h, cache, pos, cfg, blk)
        else:
            y, cache = A.attention_decode(p["attn"], h, cache, pos, cfg, blk)
        x = x + y
        if blk.cross_attn and memory is not None:
            hx = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
            gx = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
            y, _ = A.attention_decode(
                p["xattn"], hx, None, pos, cfg, blk, memory=memory
            )
            x = x + gx * y
        if blk.mlp:
            x = x + _ffn_apply(p["ffn"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg, blk)
    elif blk.kind == "recurrent":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = R.recurrent_block_step(p["rec"], h, cache)
        x = x + y
        if blk.mlp:
            x = x + _ffn_apply(p["ffn"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg, blk)
    elif blk.kind == "mlstm":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = R.mlstm_step(p["cell"], h, cache, cfg.num_heads)
        x = x + y
    elif blk.kind == "slstm":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = R.slstm_step(p["cell"], h, cache, cfg.num_heads)
        x = x + y
    return x, cache


def block_init_cache(cfg, blk, batch: int, cache_len: int, dtype):
    if blk.kind == "attn":
        if cfg.mla is not None:
            return MLA.mla_init_cache(cfg, batch, cache_len, dtype)
        return A.init_cache(cfg, blk, batch, cache_len, dtype)
    if blk.kind == "recurrent":
        return R.recurrent_block_init_state(batch, cfg.d_rnn, cfg.conv_width, dtype)
    if blk.kind == "mlstm":
        dh = 2 * cfg.d_model // cfg.num_heads
        return R.mlstm_init_state(batch, cfg.num_heads, dh)
    if blk.kind == "slstm":
        return R.slstm_init_state(batch, cfg.num_heads, cfg.d_model // cfg.num_heads)
    raise ValueError(blk.kind)
