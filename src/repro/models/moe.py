"""Mixture-of-Experts FFN with sort-based, static-capacity routing.

Routing is the sorted-scatter formulation (tokens sorted by assigned expert,
positions beyond the static capacity dropped) rather than the dense
(N, E, C) one-hot dispatch -- the latter's memory is infeasible at
arctic/deepseek scale.  Under the production mesh, experts are sharded over
the "model" axis (expert parallelism); GSPMD turns the gather/scatter between
token-sharded and expert-sharded layouts into all-to-alls.

Supports deepseek-v2 (shared experts + top-6 of 160 routed) and arctic
(dense residual MLP in parallel with top-2 of 128).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    std = 1.0 / np.sqrt(d)
    p = {
        "router": {"w": (jax.random.normal(keys[0], (d, m.num_experts), jnp.float32) * std)},
        "wg": (jax.random.normal(keys[1], (m.num_experts, d, m.expert_ff), jnp.float32) * std).astype(dtype),
        "wi": (jax.random.normal(keys[2], (m.num_experts, d, m.expert_ff), jnp.float32) * std).astype(dtype),
        "wo": (jax.random.normal(keys[3], (m.num_experts, m.expert_ff, d), jnp.float32) * (1.0 / np.sqrt(m.expert_ff))).astype(dtype),
    }
    if m.num_shared:
        p["shared"] = L.swiglu_init(keys[4], d, m.expert_ff * m.num_shared, dtype)
    if m.dense_residual_ff:
        p["dense"] = L.swiglu_init(keys[5], d, m.dense_residual_ff, dtype)
    return p


def capacity(num_tokens: int, m) -> int:
    c = int(np.ceil(m.top_k * num_tokens / m.num_experts * m.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _route_group(xf, p, m, cap):
    """Route one token group (n, D) -> (n, D).  Sort-based, capacity-dropped."""
    n, d = xf.shape
    e, k = m.num_experts, m.top_k
    logits = jnp.einsum(
        "nd,de->ne", xf, p["router"]["w"], preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                  # (n, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1).astype(jnp.int32)           # (n*k,)
    flat_t = (jnp.arange(n * k, dtype=jnp.int32) // k)
    flat_g = gate.reshape(-1)

    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32))
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)       # overflow -> pad row

    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(xf[st])
    h = buf[: e * cap].reshape(e, cap, d)
    g_ = jnp.einsum("ecd,edf->ecf", h, p["wg"], preferred_element_type=jnp.float32)
    u_ = jnp.einsum("ecd,edf->ecf", h, p["wi"], preferred_element_type=jnp.float32)
    y = jnp.einsum(
        "ecf,efd->ecd",
        (jax.nn.silu(g_) * u_).astype(xf.dtype),
        p["wo"],
        preferred_element_type=jnp.float32,
    ).astype(xf.dtype)

    yf = jnp.concatenate([y.reshape(e * cap, d), jnp.zeros((1, d), xf.dtype)], 0)
    contrib = yf[slot] * (sg * keep.astype(jnp.float32))[:, None].astype(xf.dtype)
    return jnp.zeros((n, d), xf.dtype).at[st].add(contrib)


def moe_apply(p, x, cfg):
    """Grouped routing: tokens route within ``routing_groups`` groups so the
    argsort/scatter stay local to a data shard (a single global sort is
    replicated by GSPMD -- 100s of GB at deepseek scale; see EXPERIMENTS.md)."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    groups = max(1, min(m.routing_groups, n))
    while n % groups:
        groups //= 2
    ng = n // groups
    cap = capacity(ng, m)
    xg = x.reshape(groups, ng, d)
    out = jax.vmap(lambda xf: _route_group(xf, p, m, cap))(xg)
    out = out.reshape(b, s, d)

    if "shared" in p:
        out = out + L.swiglu(p["shared"], x)
    if "dense" in p:
        out = out + L.swiglu(p["dense"], x)
    return out


def aux_load_balance_loss(logits, eidx, num_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (optional, returned by train)."""
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)
    one_hot = jax.nn.one_hot(eidx[:, 0], num_experts)
    ce = one_hot.mean(axis=0)
    return num_experts * jnp.sum(me * ce)
