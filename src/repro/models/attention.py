"""Attention: GQA/MHA/MQA, local (sliding-window), cross, and MLA variants.

The training/prefill path is a pure-JAX flash formulation: online-softmax
over key chunks inside a map over query chunks, so the (Sq, Sk) score matrix
is never materialized -- required for the 32k shapes (a 32k x 32k score
tensor would be ~TBs).  The decode path scores one query against the KV
cache; local attention uses a ring-buffer cache of window size so the
long_500k recurrent/hybrid cells carry O(window) state.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

NEG_INF = -1.0e30


class AttnDims(NamedTuple):
    heads: int
    kv_heads: int
    head_dim: int


# ------------------------------------------------------------- init --------


def attn_init(key, d_model, dims: AttnDims, dtype, *, qkv_bias=False, qk_norm=False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, dh = dims
    p = {
        "wq": L.dense_init(kq, d_model, h * dh, dtype, bias=qkv_bias),
        "wk": L.dense_init(kk, d_model, kvh * dh, dtype, bias=qkv_bias),
        "wv": L.dense_init(kv, d_model, kvh * dh, dtype, bias=qkv_bias),
        "wo": L.dense_init(ko, h * dh, d_model, dtype),
    }
    if qk_norm:
        p["qnorm"] = L.rmsnorm_init(dh, dtype)
        p["knorm"] = L.rmsnorm_init(dh, dtype)
    return p


# ------------------------------------------------------ flash attention ----


def _flash(q, k, v, qpos, kpos, *, causal: bool, window: int,
           q_chunk: int, k_chunk: int, remat_kv: bool = True,
           scale: Optional[float] = None):
    """Online-softmax attention.

    q: (B, Sq, KV, G, dh)   k, v: (B, Sk, KV, dh)
    qpos: (Sq,) kpos: (Sk,) absolute positions (mask built on the fly).
    Returns (B, Sq, KV, G, dh) in q.dtype.
    """
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]            # may differ from dh (MLA)
    if scale is None:
        scale = 1.0 / np.sqrt(dh)
    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    pad_q = (-sq) % qc
    pad_k = (-sk) % kc
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    qpos_p = jnp.pad(qpos, (0, pad_q), constant_values=-(10**9))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kpos_p = jnp.pad(kpos, (0, pad_k), constant_values=10**9)
    nq, nk = qp.shape[1] // qc, kp.shape[1] // kc

    k_ch = kp.reshape(b, nk, kc, kvh, dh).transpose(1, 0, 2, 3, 4)
    v_ch = vp.reshape(b, nk, kc, kvh, dv).transpose(1, 0, 2, 3, 4)
    kpos_ch = kpos_p.reshape(nk, kc)

    def q_block(args):
        qb, qposb = args                      # (B, qc, KV, G, dh), (qc,)

        def kv_step(carry, xs):
            m, l, acc = carry
            kb, vb, kposb = xs                # (B, kc, KV, dh), ..., (kc,)
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs", qb, kb, preferred_element_type=jnp.float32
            ) * scale                          # (B, KV, G, qc, kc)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= kposb[None, :] <= qposb[:, None]
            if window > 0:
                mask &= kposb[None, :] > (qposb[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vb, preferred_element_type=jnp.float32
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, dv), jnp.float32)
        # remat_kv: recompute score chunks in the backward instead of saving
        # the (B, KV, G, qc, kc) fp32 exp-score residual per k step
        step = jax.checkpoint(kv_step) if remat_kv else kv_step
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (k_ch, v_ch, kpos_ch))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return out.transpose(0, 3, 1, 2, 4)   # (B, qc, KV, G, dh)

    q_blocks = qp.reshape(b, nq, qc, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qpos_blocks = qpos_p.reshape(nq, qc)
    out = jax.lax.map(q_block, (q_blocks, qpos_blocks))   # (nq, B, qc, KV, G, dh)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * qc, kvh, g, dv)
    return out[:, :sq].astype(q.dtype)


# ------------------------------------------------- train/prefill forward ---


def attention(p, x, positions, cfg, block, *, memory=None, memory_pos=None,
              causal=True, return_kv=False):
    """Self- or cross-attention over a full sequence.

    x: (B, S, D); positions: (S,) int32.
    memory: (B, Sm, D_mem) for cross-attention (already projected to d_model
    by the caller if needed).
    Returns (B, S, D), and the projected (k, v) when ``return_kv`` (prefill
    cache fill).
    """
    dims = AttnDims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_)
    h, kvh, dh = dims
    g = h // kvh
    b, s, _ = x.shape

    q = L.dense(p["wq"], x).reshape(b, s, kvh, g, dh)
    src = memory if memory is not None else x
    sm = src.shape[1]
    k = L.dense(p["wk"], src).reshape(b, sm, kvh, dh)
    v = L.dense(p["wv"], src).reshape(b, sm, kvh, dh)

    if "qnorm" in p:
        q = L.rmsnorm(p["qnorm"], q, cfg.norm_eps)
        k = L.rmsnorm(p["knorm"], k, cfg.norm_eps)

    cross = memory is not None
    if not cross:
        cos, sin = L.rope_cos_sin(positions, dh, block.rope_theta)
        q = apply_rope_grouped(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        kpos = positions
    else:
        kpos = (
            memory_pos
            if memory_pos is not None
            else jnp.arange(sm, dtype=jnp.int32)
        )

    out = _flash(
        q, k, v, positions, kpos,
        causal=causal and not cross, window=block.window if not cross else 0,
        q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk, remat_kv=cfg.flash_remat,
    )
    y = L.dense(p["wo"], out.reshape(b, s, h * dh))
    if return_kv:
        return y, (k, v)
    return y


def apply_rope_grouped(q, cos, sin):
    """RoPE on (B, S, KV, G, dh)."""
    b, s, kvh, g, dh = q.shape
    return L.apply_rope(q.reshape(b, s, kvh * g, dh), cos, sin).reshape(q.shape)


# --------------------------------------------------------------- decode ----


def init_cache(cfg, block, batch: int, cache_len: int, dtype):
    """KV cache for one attention block.

    Local attention keeps a ring buffer of ``window`` slots (constant-memory
    long-context decode); global attention keeps ``cache_len`` slots.
    ``pos`` records the absolute position stored in each slot (-1 = empty).
    """
    dims = AttnDims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_)
    slots = min(block.window, cache_len) if block.window > 0 else cache_len
    return {
        "k": jnp.zeros((batch, slots, dims.kv_heads, dims.head_dim), dtype),
        "v": jnp.zeros((batch, slots, dims.kv_heads, dims.head_dim), dtype),
        "pos": jnp.full((slots,), -1, jnp.int32),
    }


def attention_decode(p, x, cache, pos, cfg, block, *, memory=None):
    """One-token decode. x: (B, 1, D); pos: scalar int32 absolute position.

    Returns (out (B, 1, D), new_cache).
    """
    dims = AttnDims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_)
    h, kvh, dh = dims
    g = h // kvh
    b = x.shape[0]

    q = L.dense(p["wq"], x).reshape(b, 1, kvh, g, dh)
    if memory is not None:  # cross-attn: static memory, no cache update
        sm = memory.shape[1]
        k = L.dense(p["wk"], memory).reshape(b, sm, kvh, dh)
        v = L.dense(p["wv"], memory).reshape(b, sm, kvh, dh)
        if "qnorm" in p:
            q = L.rmsnorm(p["qnorm"], q, cfg.norm_eps)
            k = L.rmsnorm(p["knorm"], k, cfg.norm_eps)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32
        )[:, :, :, 0] / np.sqrt(dh)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", w, v, preferred_element_type=jnp.float32)
        out = out.reshape(b, 1, h * dh).astype(x.dtype)
        return L.dense(p["wo"], out), cache

    k1 = L.dense(p["wk"], x).reshape(b, 1, kvh, dh)
    v1 = L.dense(p["wv"], x).reshape(b, 1, kvh, dh)
    if "qnorm" in p:
        q = L.rmsnorm(p["qnorm"], q, cfg.norm_eps)
        k1 = L.rmsnorm(p["knorm"], k1, cfg.norm_eps)

    posv = jnp.asarray(pos, jnp.int32)
    cos, sin = L.rope_cos_sin(posv[None], dh, block.rope_theta)
    q = apply_rope_grouped(q, cos, sin)
    k1 = L.apply_rope(k1, cos, sin)

    slots = cache["k"].shape[1]
    slot = posv % slots  # ring buffer; identity when slots == cache_len > pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k1.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v1.astype(cache["v"].dtype), (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], posv[None], (slot,))

    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", q, ck.astype(q.dtype), preferred_element_type=jnp.float32
    )[:, :, :, 0] / np.sqrt(dh)                       # (B, KV, G, slots)
    valid = (cpos >= 0) & (cpos <= posv)
    if block.window > 0:
        valid &= cpos > (posv - block.window)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", w, cv.astype(q.dtype), preferred_element_type=jnp.float32
    ).reshape(b, 1, h * dh).astype(x.dtype)
    return L.dense(p["wo"], out), {"k": ck, "v": cv, "pos": cpos}
