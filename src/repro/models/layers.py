"""Elementary layers (functional style: init_* returns a param pytree dict,
apply functions are pure).  Numerics policy (DESIGN.md #6): params in
``cfg.param_dtype``, activations in ``cfg.activation_dtype``, every matmul
accumulates in float32 via ``preferred_element_type``, norms/softmax in fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dt(name: str):
    return jnp.dtype(name)


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False, scale: Optional[float] = None):
    std = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    y = jnp.einsum("...i,io->...o", x, p["w"], preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(out_dtype)


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p, ids, out_dtype):
    return jnp.take(p["table"], ids, axis=0).astype(out_dtype)


def unembed(p_embed, x):
    """Tied readout: x @ table^T, fp32 logits."""
    return jnp.einsum(
        "...d,vd->...v", x, p_embed["table"], preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------- RoPE -----


def rope_cos_sin(positions, dim: int, theta: float):
    """positions (...,) int32 -> (..., dim/2) cos & sin, fp32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, n_heads, dh); cos/sin (..., S, dh/2) -- NeoX half split."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLPs -----


def swiglu_init(key, d: int, f: int, dtype):
    kg, ki, ko = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, d, f, dtype),
        "wi": dense_init(ki, d, f, dtype),
        "wo": dense_init(ko, f, d, dtype),
    }


def swiglu(p, x):
    g = dense(p["wg"], x, jnp.float32)
    u = dense(p["wi"], x, jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return dense(p["wo"], h)


def gelu_mlp_init(key, d: int, f: int, dtype):
    ki, ko = jax.random.split(key)
    return {
        "wi": dense_init(ki, d, f, dtype, bias=True),
        "wo": dense_init(ko, f, d, dtype, bias=True),
    }


def gelu_mlp(p, x):
    h = jax.nn.gelu(dense(p["wi"], x, jnp.float32)).astype(x.dtype)
    return dense(p["wo"], h)


def softcap(x, cap: float):
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


def blocked_cross_entropy(
    x, labels, *, table=None, w=None, bias=None, chunk: int = 8192,
    logit_softcap: float = 0.0,
):
    """Streaming CE loss over vocab chunks -- logits are NEVER materialized.

    The (B, S, V) fp32 logits of a 256k vocab are ~65 GB per device at the
    train_4k shape; this computes max/logsumexp/label-logit chunk by chunk
    (online softmax over the vocab axis) with rematerialized backward, so
    peak memory is (B, S, chunk).  Handles non-divisible vocab via an
    overlapping last chunk with first-seen masking.

    x: (B, S, D); labels: (B, S) int32 (negative = masked out).
    table: (V, D) tied embedding, or w: (D, V) untied unembed matrix.
    Returns mean loss over unmasked positions (fp32 scalar).
    """
    v = table.shape[0] if table is not None else w.shape[1]
    chunk = min(chunk, v)
    nc = -(-v // chunk)
    starts = [i * chunk for i in range(nc)]
    valid_from = list(starts)
    if starts[-1] + chunk > v:       # overlap the last chunk; mask re-seen cols
        starts[-1] = v - chunk
    starts = jnp.asarray(starts, jnp.int32)
    valid_from = jnp.asarray(valid_from, jnp.int32)

    b, s, _ = x.shape
    # masked (negative) labels pick index 0 -- the -inf never reaches the
    # loss because the mask zeroes those positions (avoid 0 * inf = NaN)
    lab = jnp.where(labels >= 0, labels, 0).astype(jnp.int32)

    def body(carry, xs):
        m, z, picked = carry
        start, vfrom = xs
        if table is not None:
            wc = jax.lax.dynamic_slice(table, (start, 0), (chunk, table.shape[1]))
            lc = jnp.einsum("bsd,cd->bsc", x, wc, preferred_element_type=jnp.float32)
        else:
            wc = jax.lax.dynamic_slice(w, (0, start), (w.shape[0], chunk))
            lc = jnp.einsum("bsd,dc->bsc", x, wc, preferred_element_type=jnp.float32)
        if bias is not None:
            lc = lc + jax.lax.dynamic_slice(bias, (start,), (chunk,)).astype(jnp.float32)
        lc = softcap(lc, logit_softcap)
        gcol = start + jnp.arange(chunk, dtype=jnp.int32)
        seen_first = gcol >= vfrom
        lc = jnp.where(seen_first[None, None, :], lc, -jnp.inf)
        m_new = jnp.maximum(m, lc.max(axis=-1))
        z = z * jnp.exp(m - m_new) + jnp.exp(lc - m_new[..., None]).sum(axis=-1)
        local = lab - start
        in_chunk = (local >= 0) & (local < chunk) & (lab - vfrom >= 0)
        safe = jnp.clip(local, 0, chunk - 1)
        got = jnp.take_along_axis(lc, safe[..., None], axis=-1)[..., 0]
        picked = jnp.where(in_chunk & (got > -jnp.inf), got, picked)
        return (m_new, z, picked), None

    init = (
        jnp.full((b, s), -jnp.inf, jnp.float32),
        jnp.zeros((b, s), jnp.float32),
        jnp.full((b, s), -jnp.inf, jnp.float32),
    )
    (m, z, picked), _ = jax.lax.scan(
        jax.checkpoint(body), init, (starts, valid_from)
    )
    ll = picked - m - jnp.log(jnp.maximum(z, 1e-37))
    mask = (labels >= 0).astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
