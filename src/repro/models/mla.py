"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Training/prefill uses the decompressed form; decode uses the *absorbed* form:
the KV up-projection is folded into the query/output paths so the cache holds
only the 512-dim latent c_kv plus the 64-dim decoupled RoPE key -- the paper's
93% cache reduction, and the reason deepseek-v2's decode cells are far less
HBM-bound than GQA at the same scale (see EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.attention import _flash, NEG_INF


def mla_init(key, cfg, dtype):
    m = cfg.mla
    d = cfg.d_model
    h = cfg.num_heads
    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    keys = jax.random.split(key, 6)
    return {
        "wdq": L.dense_init(keys[0], d, m.q_lora_rank, dtype),
        "qnorm": L.rmsnorm_init(m.q_lora_rank, dtype),
        "wuq": L.dense_init(keys[1], m.q_lora_rank, h * dqk, dtype),
        "wdkv": L.dense_init(keys[2], d, m.kv_lora_rank, dtype),
        "kvnorm": L.rmsnorm_init(m.kv_lora_rank, dtype),
        "wukv": L.dense_init(
            keys[3], m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dtype
        ),
        "wkr": L.dense_init(keys[4], d, m.qk_rope_head_dim, dtype),
        "wo": L.dense_init(keys[5], h * m.v_head_dim, d, dtype),
    }


def _project_q(p, x, cfg, positions):
    m = cfg.mla
    h = cfg.num_heads
    b, s, _ = x.shape
    cq = L.rmsnorm(p["qnorm"], L.dense(p["wdq"], x), cfg.norm_eps)
    q = L.dense(p["wuq"], cq).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    qn, qr = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    cos, sin = L.rope_cos_sin(positions, m.qk_rope_head_dim, 10_000.0)
    qr = L.apply_rope(qr, cos, sin)
    return qn, qr


def mla_attention(p, x, positions, cfg, block):
    """Train/prefill (decompressed) MLA. x: (B, S, D) -> (B, S, D)."""
    m = cfg.mla
    h = cfg.num_heads
    b, s, _ = x.shape
    qn, qr = _project_q(p, x, cfg, positions)

    ckv = L.rmsnorm(p["kvnorm"], L.dense(p["wdkv"], x), cfg.norm_eps)   # (B,S,r_kv)
    kr = L.dense(p["wkr"], x)                                           # (B,S,dr)
    cos, sin = L.rope_cos_sin(positions, m.qk_rope_head_dim, 10_000.0)
    kr = L.apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0]             # single head
    kv = L.dense(p["wukv"], ckv).reshape(
        b, s, h, m.qk_nope_head_dim + m.v_head_dim
    )
    kn, v = kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim :]

    q = jnp.concatenate([qn, qr], axis=-1)[:, :, :, None, :]            # (B,S,H,1,dqk)
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(kr[:, :, None, :], (b, s, h, m.qk_rope_head_dim))],
        axis=-1,
    )                                                                   # (B,S,H,dqk)
    out = _flash(
        q, k, v, positions, positions,
        causal=True, window=0, q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk,
        remat_kv=cfg.flash_remat,
    )                                                                   # (B,S,H,1,dv)
    return L.dense(p["wo"], out.reshape(b, s, h * m.v_head_dim))


def mla_attention_absorbed(p, x, positions, cfg, block):
    """Absorbed-form MLA for train/prefill: the KV up-projection is folded
    into the query/output paths, so attention runs MQA-style against the
    SHARED (kv_lora + rope)-dim latent -- no per-head K/V materialization
    (128 heads x 192 dims otherwise; see EXPERIMENTS.md #Perf cell C).
    Mathematically identical to ``mla_attention``; score/value FLOPs rise
    (contraction over 576 vs 320 dims) in exchange for ~H x less K/V traffic.
    """
    m = cfg.mla
    h = cfg.num_heads
    b, s, _ = x.shape
    qn, qr = _project_q(p, x, cfg, positions)                  # (B,S,H,dn/dr)

    ckv = L.rmsnorm(p["kvnorm"], L.dense(p["wdkv"], x), cfg.norm_eps)  # (B,S,r)
    kr = L.dense(p["wkr"], x)
    cos, sin = L.rope_cos_sin(positions, m.qk_rope_head_dim, 10_000.0)
    kr = L.apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0]            # (B,S,dr)

    wukv = p["wukv"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    wuk = wukv[..., : m.qk_nope_head_dim]
    wuv = wukv[..., m.qk_nope_head_dim :]

    q_eff = jnp.einsum("bshd,rhd->bshr", qn, wuk,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    q_cat = jnp.concatenate([q_eff, qr], axis=-1)              # (B,S,H,r+dr)
    k_cat = jnp.concatenate([ckv, kr], axis=-1)[:, :, None, :]  # (B,S,1,r+dr)
    v_lat = ckv[:, :, None, :]                                  # (B,S,1,r)

    out = _flash(
        q_cat.reshape(b, s, 1, h, m.kv_lora_rank + m.qk_rope_head_dim),
        k_cat, v_lat, positions, positions,
        causal=True, window=0, q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk,
        remat_kv=cfg.flash_remat,
        scale=1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim),
    )                                                           # (B,S,1,H,r)
    o_lat = out[:, :, 0]                                        # (B,S,H,r)
    y = jnp.einsum("bshr,rhd->bshd", o_lat.astype(jnp.float32), wuv,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return L.dense(p["wo"], y.reshape(b, s, h * m.v_head_dim))


def mla_init_cache(cfg, batch: int, cache_len: int, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((cache_len,), -1, jnp.int32),
    }


def mla_decode(p, x, cache, pos, cfg, block):
    """Absorbed-form decode. x: (B, 1, D); cache holds (c_kv, k_rope)."""
    m = cfg.mla
    h = cfg.num_heads
    b = x.shape[0]
    posv = jnp.asarray(pos, jnp.int32)

    qn, qr = _project_q(p, x, cfg, posv[None])                          # (B,1,H,*)

    ckv1 = L.rmsnorm(p["kvnorm"], L.dense(p["wdkv"], x), cfg.norm_eps)  # (B,1,r)
    kr1 = L.dense(p["wkr"], x)
    cos, sin = L.rope_cos_sin(posv[None], m.qk_rope_head_dim, 10_000.0)
    kr1 = L.apply_rope(kr1[:, :, None, :], cos, sin)[:, :, 0]           # (B,1,dr)

    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv1.astype(cache["ckv"].dtype), (0, posv, 0)
    )
    kr = jax.lax.dynamic_update_slice(
        cache["kr"], kr1.astype(cache["kr"].dtype), (0, posv, 0)
    )
    cpos = jax.lax.dynamic_update_slice(cache["pos"], posv[None], (posv,))

    wukv = p["wukv"]["w"].reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim
    )
    wuk = wukv[..., : m.qk_nope_head_dim]                               # (r, H, dn)
    wuv = wukv[..., m.qk_nope_head_dim :]                               # (r, H, dv)

    # absorb K up-projection into q: q_eff (B, H, r)
    q_eff = jnp.einsum(
        "bhd,rhd->bhr", qn[:, 0], wuk, preferred_element_type=jnp.float32
    )
    s_lat = jnp.einsum(
        "bhr,bsr->bhs", q_eff, ckv.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    s_rope = jnp.einsum(
        "bhd,bsd->bhs", qr[:, 0].astype(jnp.float32), kr.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (s_lat + s_rope) * scale
    valid = (cpos >= 0) & (cpos <= posv)
    s = jnp.where(valid[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)

    o_lat = jnp.einsum(
        "bhs,bsr->bhr", w, ckv.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out = jnp.einsum(
        "bhr,rhd->bhd", o_lat, wuv, preferred_element_type=jnp.float32
    ).reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    return L.dense(p["wo"], out), {"ckv": ckv, "kr": kr, "pos": cpos}
