"""Unified model configuration covering the 10 assigned architectures.

A model is a stack of *layer groups*; each group is a repeating *pattern* of
blocks (e.g. gemma3's 5 local + 1 global attention layers).  Patterns keep
the HLO small: within a group, layers are lax.scan'ned over the repeat axis
with stacked parameters, so a 64-layer model lowers to one block body per
distinct pattern position.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    """One block position inside a layer-group pattern."""

    kind: str                    # attn | recurrent | mlstm | slstm
    window: int = 0              # >0: local (sliding-window) attention
    cross_attn: bool = False     # adds a cross-attention sub-block (VLM/encdec)
    moe: bool = False            # MoE FFN instead of dense FFN
    rope_theta: float = 10_000.0
    bidirectional: bool = False  # encoder self-attention (no causal mask)
    mlp: bool = True             # False: block has no FFN sub-block (xLSTM)


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int = 0
    top_k: int = 2
    expert_ff: int = 0           # per-expert hidden size
    num_shared: int = 0          # always-on shared experts (deepseek)
    dense_residual_ff: int = 0   # parallel dense FFN (arctic's dense residual)
    capacity_factor: float = 1.25
    # tokens are routed within groups so the routing sort stays local to a
    # data shard instead of a replicated global sort (EXPERIMENTS.md #Perf)
    routing_groups: int = 32


@dataclasses.dataclass(frozen=True)
class MLACfg:
    """Multi-head latent attention (deepseek-v2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    # layer structure: tuple of (pattern, repeat); total layers = sum(len(p)*r)
    groups: Tuple[Tuple[Tuple[BlockCfg, ...], int], ...]
    head_dim: Optional[int] = None        # default d_model // num_heads
    qk_norm: bool = False                 # qwen3
    qkv_bias: bool = False                # qwen2.5
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    max_seq: int = 131_072
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    # recurrent blocks
    d_rnn: int = 0                        # RG-LRU width (recurrentgemma: d_model)
    conv_width: int = 4
    # encoder-decoder (seamless): encoder defined by enc_* fields
    encoder_groups: Optional[Tuple[Tuple[Tuple[BlockCfg, ...], int], ...]] = None
    enc_input_dim: int = 0                # stub frontend embedding width
    # vision stub (llama-3.2-vision): cross-attn memory width
    vision_tokens: int = 0
    vision_dim: int = 0
    # numerics / memory policy
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"      # bf16 for >=236B configs (DESIGN.md #4)
    remat: bool = True
    # remat granularity (EXPERIMENTS.md #Perf): "pattern" checkpoints the
    # whole repeat-body (min saved, max recompute peak -- all pattern blocks'
    # residuals live at once in backward); "block" checkpoints each block
    # (saves inter-block activations, peak = one block); "double" nests both.
    remat_mode: str = "block"
    flash_remat: bool = True              # recompute flash score chunks in bwd
    # absorbed-form MLA outside decode: refuted by measurement -- GSPMD
    # re-gathers the replicated 576-d latent per flash chunk, trading the
    # K/V-traffic win for a 3x collective regression (EXPERIMENTS.md #Perf
    # cell B iter 3).  Decode always uses the absorbed form (separate path).
    mla_absorbed: bool = False
    logit_softcap: float = 0.0            # gemma-style final-logit softcap
    # attention chunking (online-softmax flash form)
    q_chunk: int = 512
    k_chunk: int = 1024
    # streaming cross-entropy vocab chunk (train path; 0 = materialize logits)
    ce_chunk: int = 8192
    # architecture family tag used by shape-applicability logic
    family: str = "dense"                 # dense | moe | hybrid | ssm | audio | vlm
    sub_quadratic: bool = False           # can run long_500k decode

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_layers(self) -> int:
        total = sum(len(p) * r for p, r in self.groups)
        return total

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline math)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


def dense_stack(block: BlockCfg, layers: int):
    return (((block,), layers),)
