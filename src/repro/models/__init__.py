from repro.models.config import BlockCfg, MLACfg, MoECfg, ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    abstract_params,
    count_params_analytic,
    decode_step,
    forward_loss,
    forward_train,
    init_caches,
    init_params,
    prefill,
)
