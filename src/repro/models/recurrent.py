"""Recurrent sequence mixers: RG-LRU (RecurrentGemma/Griffin) and xLSTM cells.

All three expose a *sequence* form (train/prefill; O(S) or chunkwise-parallel,
TPU-friendly) and a *step* form (decode; O(1) state) sharing the same state
pytree -- this is what makes the long_500k decode shape constant-memory for
the hybrid/ssm architectures (DESIGN.md #3).

  * RG-LRU: diagonal gated linear recurrence; sequence form uses
    ``jax.lax.associative_scan`` (log-depth on TPU).
  * mLSTM: matrix-memory LSTM; sequence form is chunkwise-parallel with
    running-max stabilization of the exponential gates (intra-chunk quadratic
    on the MXU, inter-chunk recurrent state).
  * sLSTM: scalar-memory LSTM with per-head recurrent weights; inherently
    sequential -> lax.scan over time.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

# ======================================================= RG-LRU (Griffin) ==


def rglru_init(key, d_rnn: int, dtype):
    ka, kx, kl = jax.random.split(key, 3)
    return {
        "wa": L.dense_init(ka, d_rnn, d_rnn, dtype),
        "wx": L.dense_init(kx, d_rnn, d_rnn, dtype),
        # lambda init so decay a = exp(-8 softplus(lam) r) ~ 0.9..0.99
        "lam": jax.random.uniform(kl, (d_rnn,), jnp.float32, -4.6, -3.0),
    }


def _rglru_gates(p, x):
    r = jax.nn.sigmoid(L.dense(p["wa"], x, jnp.float32))
    i = jax.nn.sigmoid(L.dense(p["wx"], x, jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalization (Griffin eq. 4)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x.astype(jnp.float32)
    )
    return a, b


def rglru_seq(p, x, h0=None):
    """x: (B, S, d_rnn) -> (y (B,S,d_rnn), h_last (B,d_rnn)).  h_t = a h + b."""
    a, b = _rglru_gates(p, x)

    def comb(c1, c2):  # c1 earlier, c2 later
        return (c1[0] * c2[0], c2[0] * c1[1] + c2[1])

    a_s, b_s = jax.lax.associative_scan(comb, (a, b), axis=1)
    h = b_s
    if h0 is not None:
        h = h + a_s * h0[:, None, :].astype(jnp.float32)
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)


def rglru_step(p, x1, h):
    """x1: (B, 1, d_rnn), h: (B, d_rnn) -> (y (B,1,d), h_new)."""
    a, b = _rglru_gates(p, x1)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(x1.dtype)[:, None, :], h_new


def conv1d_init(key, width: int, d: int, dtype):
    return {
        "w": (jax.random.normal(key, (width, d), jnp.float32) / np.sqrt(width)).astype(dtype),
        "b": jnp.zeros((d,), dtype),
    }


def conv1d_seq(p, x):
    """Causal depthwise conv, width w. x: (B, S, d)."""
    w = p["w"].shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(w):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted.astype(jnp.float32) * p["w"][w - 1 - i].astype(jnp.float32)
    return (out + p["b"].astype(jnp.float32)).astype(x.dtype)


def conv1d_step(p, x1, hist):
    """x1: (B,1,d); hist: (B, w-1, d) previous inputs -> (y, new_hist)."""
    w = p["w"].shape[0]
    seq = jnp.concatenate([hist, x1.astype(hist.dtype)], axis=1)  # (B, w, d)
    y = jnp.einsum(
        "bwd,wd->bd", seq.astype(jnp.float32), p["w"].astype(jnp.float32)
    ) + p["b"].astype(jnp.float32)
    return y.astype(x1.dtype)[:, None], seq[:, 1:]


def recurrent_block_init(key, d_model: int, d_rnn: int, conv_width: int, dtype):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "win1": L.dense_init(k1, d_model, d_rnn, dtype),
        "win2": L.dense_init(k2, d_model, d_rnn, dtype),
        "conv": conv1d_init(k3, conv_width, d_rnn, dtype),
        "rglru": rglru_init(k4, d_rnn, dtype),
        "wout": L.dense_init(k5, d_rnn, d_model, dtype),
    }


def recurrent_block_seq(p, x, state=None):
    """Griffin recurrent block, sequence form. x: (B,S,D)."""
    b1 = L.dense(p["win1"], x)
    gate = jax.nn.gelu(L.dense(p["win2"], x, jnp.float32)).astype(x.dtype)
    c = conv1d_seq(p["conv"], b1)
    h0 = state["h"] if state is not None else None
    y, h_last = rglru_seq(p["rglru"], c, h0)
    out = L.dense(p["wout"], y * gate)
    new_state = {
        "h": h_last,
        "conv": b1[:, -(p["conv"]["w"].shape[0] - 1):].astype(x.dtype),
    }
    return out, new_state


def recurrent_block_step(p, x1, state):
    b1 = L.dense(p["win1"], x1)
    gate = jax.nn.gelu(L.dense(p["win2"], x1, jnp.float32)).astype(x1.dtype)
    c, conv_hist = conv1d_step(p["conv"], b1, state["conv"])
    y, h = rglru_step(p["rglru"], c, state["h"])
    out = L.dense(p["wout"], y * gate)
    return out, {"h": h, "conv": conv_hist}


def recurrent_block_init_state(batch: int, d_rnn: int, conv_width: int, dtype):
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_rnn), dtype),
    }


# ================================================================ mLSTM ====


def mlstm_init(key, d_model: int, num_heads: int, d_inner: int, dtype):
    kq, kk, kv, ki, kf, ko, kp, kn = jax.random.split(key, 8)
    return {
        "wq": L.dense_init(kq, d_model, d_inner, dtype),
        "wk": L.dense_init(kk, d_model, d_inner, dtype),
        "wv": L.dense_init(kv, d_model, d_inner, dtype),
        "wi": L.dense_init(ki, d_model, num_heads, dtype, bias=True),
        "wf": L.dense_init(kf, d_model, num_heads, dtype, bias=True),
        "wog": L.dense_init(ko, d_model, d_inner, dtype),
        "norm": L.rmsnorm_init(d_inner, dtype),
        "wout": L.dense_init(kp, d_inner, d_model, dtype),
    }


def _mlstm_qkv(p, x, num_heads):
    b, s, _ = x.shape
    dh = p["wq"]["w"].shape[1] // num_heads
    q = L.dense(p["wq"], x, jnp.float32).reshape(b, s, num_heads, dh).transpose(0, 2, 1, 3)
    k = L.dense(p["wk"], x, jnp.float32).reshape(b, s, num_heads, dh).transpose(0, 2, 1, 3)
    v = L.dense(p["wv"], x, jnp.float32).reshape(b, s, num_heads, dh).transpose(0, 2, 1, 3)
    li = L.dense(p["wi"], x, jnp.float32).transpose(0, 2, 1)            # (B,H,S) log input gate
    lf = jax.nn.log_sigmoid(L.dense(p["wf"], x, jnp.float32)).transpose(0, 2, 1)
    return q, k / np.sqrt(dh), v, li, lf


def mlstm_seq(p, x, num_heads: int, state=None, chunk: int = 128):
    """Chunkwise-parallel mLSTM. x: (B,S,D) -> (y, state).

    State: C (B,H,dk,dv), n (B,H,dk), m (B,H) with C, n stored descaled by
    exp(m) (running-max stabilization of the exponential gates).
    """
    b, s, _ = x.shape
    q, k, v, li, lf = _mlstm_qkv(p, x, num_heads)
    h_heads = num_heads
    dh = q.shape[-1]
    t = min(chunk, s)
    pad = (-s) % t
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0))) for a in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))
    nc = (s + pad) // t

    def split(a):  # (B,H,S,*) -> (nc, B,H,t,*)
        return a.reshape(b, h_heads, nc, t, *a.shape[3:]).transpose(2, 0, 1, 3, *range(4, a.ndim + 1))

    qs, ks, vs = split(q), split(k), split(v)
    lis = li.reshape(b, h_heads, nc, t).transpose(2, 0, 1, 3)
    lfs = lf.reshape(b, h_heads, nc, t).transpose(2, 0, 1, 3)

    if state is None:
        c0 = jnp.zeros((b, h_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h_heads, dh), jnp.float32)
        m0 = jnp.full((b, h_heads), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["C"], state["n"], state["m"]

    tri = jnp.tril(jnp.ones((t, t), bool))

    def chunk_step(carry, xs):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, lic, lfc = xs                       # (B,H,t,dh) / (B,H,t)
        lcum = jnp.cumsum(lfc, axis=-1)                 # L_t
        ltot = lcum[..., -1:]                           # L_T
        # intra-chunk log weights D_ts = L_t - L_s + i_s (s <= t)
        dmat = lcum[..., :, None] - lcum[..., None, :] + lic[..., None, :]
        dmat = jnp.where(tri[None, None], dmat, -1e30)
        m_intra = dmat.max(axis=-1)                     # (B,H,t)
        m_comb = jnp.maximum(m_intra, m_prev[..., None] + lcum)
        sc = jnp.einsum("bhtd,bhsd->bhts", qc, kc) * jnp.exp(
            dmat - m_comb[..., None]
        )
        inter_scale = jnp.exp(m_prev[..., None] + lcum - m_comb)      # (B,H,t)
        num = jnp.einsum("bhts,bhsd->bhtd", sc, vc) + jnp.einsum(
            "bhtd,bhdv->bhtv", qc, c_prev
        ) * inter_scale[..., None]
        # q.n_t = sum_s (q.k_s) exp(D_ts - m) = row-sum of sc (k is pre-scaled)
        den = jnp.abs(
            sc.sum(axis=-1)
            + jnp.einsum("bhtd,bhd->bht", qc, n_prev) * inter_scale
        )
        h = num / jnp.maximum(den, jnp.exp(-m_comb))[..., None]
        # state to chunk end
        a_log = ltot - lcum + lic                       # decay t..T + input gate
        m_new = jnp.maximum(m_prev + ltot[..., 0], a_log.max(axis=-1))
        w = jnp.exp(a_log - m_new[..., None])           # (B,H,t)
        c_new = c_prev * jnp.exp(m_prev + ltot[..., 0] - m_new)[..., None, None] + jnp.einsum(
            "bht,bhtd,bhtv->bhdv", w, kc, vc
        )
        n_new = n_prev * jnp.exp(m_prev + ltot[..., 0] - m_new)[..., None] + jnp.einsum(
            "bht,bhtd->bhd", w, kc
        )
        return (c_new, n_new, m_new), h

    (c_f, n_f, m_f), hs = jax.lax.scan(
        chunk_step, (c0, n0, m0), (qs, ks, vs, lis, lfs)
    )
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, h_heads, nc * t, dh)[:, :, :s]
    h = h.transpose(0, 2, 1, 3).reshape(b, s, h_heads * dh)
    og = jax.nn.sigmoid(L.dense(p["wog"], x, jnp.float32))
    y = L.rmsnorm(p["norm"], (h * og).astype(x.dtype))
    return L.dense(p["wout"], y), {"C": c_f, "n": n_f, "m": m_f}


def mlstm_step(p, x1, state, num_heads: int):
    """One-token mLSTM. x1: (B,1,D)."""
    q, k, v, li, lf = _mlstm_qkv(p, x1, num_heads)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]        # (B,H,dh)
    li, lf = li[:, :, 0], lf[:, :, 0]                   # (B,H)
    c, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fs = jnp.exp(lf + m - m_new)
    is_ = jnp.exp(li - m_new)
    c_new = c * fs[..., None, None] + is_[..., None, None] * jnp.einsum(
        "bhd,bhv->bhdv", k, v
    )
    n_new = n * fs[..., None] + is_[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(x1.shape[0], 1, -1)
    og = jax.nn.sigmoid(L.dense(p["wog"], x1, jnp.float32))
    y = L.rmsnorm(p["norm"], (h * og).astype(x1.dtype))
    return L.dense(p["wout"], y), {"C": c_new, "n": n_new, "m": m_new}


def mlstm_init_state(batch: int, num_heads: int, dh: int):
    return {
        "C": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, num_heads, dh), jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, jnp.float32),
    }


# ================================================================ sLSTM ====


def slstm_init(key, d_model: int, num_heads: int, dtype):
    dh = d_model // num_heads
    kw, kr, ko = jax.random.split(key, 3)
    return {
        "wzifo": L.dense_init(kw, d_model, 4 * d_model, dtype, bias=True),
        # per-head recurrent weights for z,i,f,o: (4, H, dh, dh)
        "r": (jax.random.normal(kr, (4, num_heads, dh, dh), jnp.float32) / np.sqrt(dh)).astype(dtype),
        "norm": L.rmsnorm_init(d_model, dtype),
        "wout": L.dense_init(ko, d_model, d_model, dtype),
    }


def slstm_seq(p, x, num_heads: int, state=None):
    """Sequential sLSTM via lax.scan. x: (B,S,D)."""
    b, s, d = x.shape
    dh = d // num_heads
    pre = L.dense(p["wzifo"], x, jnp.float32)            # (B,S,4D)
    pre = pre.reshape(b, s, 4, num_heads, dh).transpose(1, 0, 2, 3, 4)  # (S,B,4,H,dh)
    r = p["r"].astype(jnp.float32)

    if state is None:
        state = slstm_init_state(b, num_heads, dh)
    init = (state["c"], state["n"], state["m"], state["h"])

    def step(carry, xt):
        c, n, m, h = carry                               # (B,H,dh) each
        rec = jnp.einsum("bhd,ghde->gbhe", h, r)         # (4,B,H,dh)
        z = jnp.tanh(xt[:, 0] + rec[0])
        li = xt[:, 1] + rec[1]                           # log input gate
        lf = jax.nn.log_sigmoid(xt[:, 2] + rec[2])       # log forget gate
        o = jax.nn.sigmoid(xt[:, 3] + rec[3])
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c_new = f_ * c + i_ * z
        n_new = f_ * n + i_
        h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h), ys = jax.lax.scan(step, init, pre)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y)
    return L.dense(p["wout"], y), {"c": c, "n": n, "m": m, "h": h}


def slstm_step(p, x1, state, num_heads: int):
    y, new_state = slstm_seq(p, x1, num_heads, state)
    return y, new_state


def slstm_init_state(batch: int, num_heads: int, dh: int):
    z = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, num_heads, dh), -1e30, jnp.float32), "h": z}
