"""Render the roofline table from experiments/dryrun*/ JSONs.

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun [--csv]
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirpath: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        d = json.load(open(f))
        d["_tag"] = os.path.basename(f)[:-5]
        rows.append(d)
    return rows


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def markdown(rows, mesh_filter=None):
    out = []
    out.append(
        "| arch | shape | mesh | compute | memory | collective | dominant | "
        "step | frac | MODEL/HLO | MFU | HBM/chip |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        if "skipped" in d:
            arch, shape, mesh = d["_tag"].split("__")
            if mesh_filter and mesh != mesh_filter:
                continue
            out.append(
                f"| {arch} | {shape} | {mesh} | — | — | — | SKIPPED | — | — | — | — | — |"
            )
            continue
        arch, shape, mesh = d["_tag"].split("__")
        if mesh_filter and mesh != mesh_filter:
            continue
        hbm = (d.get("temp_bytes_per_chip") or 0) + (d.get("arg_bytes_per_chip") or 0)
        out.append(
            f"| {arch} | {shape} | {mesh} | {fmt_s(d['compute_s'])} | "
            f"{fmt_s(d['memory_s'])} | {fmt_s(d['collective_s'])} | "
            f"{d['dominant']} | {fmt_s(d['step_time_s'])} | "
            f"{d['roofline_fraction']:.3f} | {d['useful_flops_fraction']:.2f} | "
            f"{d['mfu']:.4f} | {hbm/1e9:.1f}GB |"
        )
    return "\n".join(out)


def main():
    dirpath = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load(dirpath)
    print(f"### {dirpath} ({len(rows)} cells)\n")
    print(markdown(rows))


if __name__ == "__main__":
    main()
