"""Three-term roofline from the compiled dry-run artifact (TPU v5e target).

  compute term    = HLO_FLOPs_per_chip / peak_FLOPs          [s]
  memory term     = HLO_bytes_per_chip / HBM_bw              [s]
  collective term = wire_bytes_per_chip / link_bw            [s]

HLO_FLOPs/bytes come from the trip-count-aware HLO parser (repro.roofline.hlo)
-- the per-partition module IS the per-chip program.  The dominant term is the
bottleneck; roofline fraction = compute_term / max(all terms).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.roofline.hlo import HloCosts, parse_hlo_module


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops: float        # per chip
    hbm_bw: float            # bytes/s per chip
    link_bw: float           # bytes/s per ICI link


V5E = HwSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float             # 6*N*D (or 6*N_active*D) GLOBAL
    xla_flops_raw: Optional[float] = None   # cost_analysis (scan-undercounted)
    xla_bytes_raw: Optional[float] = None
    collective_by_type: Dict[str, float] = dataclasses.field(default_factory=dict)
    temp_bytes: Optional[float] = None      # memory_analysis temp size
    arg_bytes: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak: compute term / bottleneck term."""
        t = self.step_time_s
        return self.compute_s / t if t else 0.0

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): remat/redundancy waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops / (self.chips * t) / V5E.peak_flops

    def as_dict(self):
        d = dataclasses.asdict(self)
        d.update(
            dominant=self.dominant,
            step_time_s=self.step_time_s,
            roofline_fraction=self.roofline_fraction,
            useful_flops_fraction=self.useful_flops_fraction,
            mfu=self.mfu,
        )
        return d


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    chips: int,
    hlo_text: str,
    model_flops: float,
    cost_analysis: Optional[dict] = None,
    memory_analysis=None,
    hw: HwSpec = V5E,
) -> RooflineReport:
    costs: HloCosts = parse_hlo_module(hlo_text)
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        chips=chips,
        flops_per_chip=costs.dot_flops,
        hbm_bytes_per_chip=costs.hbm_bytes,
        wire_bytes_per_chip=costs.collective_wire_bytes,
        compute_s=costs.dot_flops / hw.peak_flops,
        memory_s=costs.hbm_bytes / hw.hbm_bw,
        collective_s=costs.collective_wire_bytes / hw.link_bw,
        model_flops=model_flops,
        xla_flops_raw=(cost_analysis or {}).get("flops"),
        xla_bytes_raw=(cost_analysis or {}).get("bytes accessed"),
        collective_by_type=dict(costs.collective_by_type),
        temp_bytes=getattr(memory_analysis, "temp_size_in_bytes", None),
        arg_bytes=getattr(memory_analysis, "argument_size_in_bytes", None),
    )


def model_flops_train(cfg, batch: int, seq: int) -> float:
    """6*N*D with N = active params; + attention score/value FLOPs."""
    n_active = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    base = 6.0 * n_active * batch * seq
    return base + batch * _attention_flops(cfg, seq, train=True)


def model_flops_decode(cfg, batch: int, context: int) -> float:
    """Per decode step: 2*N_active*B (fwd only) + attention over the cache."""
    n_active = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    base = 2.0 * n_active * batch
    return base + _attention_flops_decode(cfg, batch, context)


def model_flops_prefill(cfg, batch: int, seq: int) -> float:
    n_active = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    return 2.0 * n_active * batch * seq + batch * _attention_flops(cfg, seq, train=False)


def _per_layer_attn_flops(cfg, q_len: int, k_len: int, fwdbwd: float) -> float:
    if cfg.mla is not None:
        dqk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        dv = cfg.mla.v_head_dim
    else:
        dqk = dv = cfg.head_dim_
    return fwdbwd * 2.0 * cfg.num_heads * q_len * k_len * (dqk + dv)


def _attention_flops(cfg, seq: int, train: bool) -> float:
    """Per-sequence causal score+value FLOPs across layers (windows clip k)."""
    fwdbwd = 3.0 if train else 1.0
    total = 0.0
    for pattern, repeat in cfg.groups:
        for blk in pattern:
            if blk.kind != "attn":
                continue
            # average causal k_len; local windows cap it
            avg_k = seq / 2.0 if blk.window <= 0 else min(blk.window, seq / 2.0)
            total += repeat * _per_layer_attn_flops(cfg, seq, avg_k, fwdbwd)
    return total


def _attention_flops_decode(cfg, batch: int, context: int) -> float:
    total = 0.0
    for pattern, repeat in cfg.groups:
        for blk in pattern:
            if blk.kind != "attn":
                continue
            k_len = min(blk.window, context) if blk.window > 0 else context
            total += repeat * batch * _per_layer_attn_flops(cfg, 1, k_len, 1.0)
    return total
