from repro.roofline.hlo import parse_hlo_module, HloCosts  # noqa: F401
from repro.roofline.analysis import roofline_terms, RooflineReport, V5E  # noqa: F401
