"""Post-SPMD HLO text analysis with while-loop trip-count propagation.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so for scanned
layer stacks it undercounts FLOPs/bytes by the trip count (verified
empirically, see EXPERIMENTS.md #Dry-run).  This module parses
``compiled.as_text()`` (per-partition HLO) and:

  * multiplies every computation's cost by its execution multiplicity
    (ENTRY=1; while body/cond inherit caller_mult x trip_count, where the
    trip count is recovered from the loop-condition constant -- scan always
    lowers to a counted loop);
  * FLOPs: 2 * prod(result_dims) * prod(contracted lhs dims) per ``dot``
    (+ convolutions), including dots inside fusion computations -- MXU work;
  * HBM bytes: per top-level op, operand + result bytes (fusion internals
    excluded: they live in registers/VMEM);
  * collectives: tensor bytes and ring wire bytes per op type with the group
    size parsed from ``replica_groups=[G,S]<=[N]``:
        all-reduce      2 x bytes x (S-1)/S
        all-gather      result_bytes x (S-1)/S
        reduce-scatter  operand_bytes x (S-1)/S
        all-to-all      bytes x (S-1)/S
        collective-permute  bytes

All numbers are PER PARTITION (the module is the per-device program), which
is what the per-chip roofline needs.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    """Total bytes of all shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: Dict[str, _Op]
    order: List[str]
    root: Optional[str] = None


@dataclasses.dataclass
class HloCosts:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_tensor_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_by_type: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )
    bytes_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    num_while_loops: int = 0

    def as_dict(self):
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_tensor_bytes": self.collective_tensor_bytes,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_by_type": dict(self.collective_by_type),
            "collective_count": dict(self.collective_count),
            "bytes_by_op": dict(self.bytes_by_op),
            "num_while_loops": self.num_while_loops,
        }


def _split_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{") and "->" in line:
                cur = _Computation(m.group(1), {}, [])
                if line.startswith("ENTRY"):
                    entry_name = m.group(1)
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rest = m.groups()
            # result type: balanced-paren tuple or a single token
            rest = rest.strip()
            if rest.startswith("("):
                depth0 = 0
                tend = 0
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth0 += 1
                    elif ch == ")":
                        depth0 -= 1
                        if depth0 == 0:
                            tend = i
                            break
                rtype = rest[: tend + 1]
                remainder = rest[tend + 1 :].strip()
            else:
                sm = re.match(r"(\S+)\s+", rest)
                if not sm:
                    continue
                rtype = sm.group(1)
                remainder = rest[sm.end() :]
            om = re.match(r"([\w\-]+)\(", remainder)
            if not om:
                continue
            opcode = om.group(1)
            paren = remainder[om.end() - 1 :]
            # operands: %refs within the first balanced paren group
            depth = 0
            end = 0
            for i, ch in enumerate(paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_str = paren[1:end]
            attrs = paren[end + 1 :]
            operands = re.findall(r"%([\w.\-]+)", operand_str)
            cur.ops[name] = _Op(name, opcode, rtype, operands, attrs, line)
            cur.order.append(name)
            if line.lstrip().startswith("ROOT"):
                cur.root = name
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(comp: _Computation) -> int:
    """Largest integer constant in a loop-condition computation."""
    best = 1
    for op in comp.ops.values():
        for m in re.finditer(r"constant\((\d+)\)", op.line):
            best = max(best, int(m.group(1)))
    return best


def _operand_type(comp: _Computation, ref: str) -> str:
    op = comp.ops.get(ref)
    return op.result_type if op else ""


def _dot_flops(comp: _Computation, op: _Op) -> float:
    res = _shape_dims(op.result_type)
    if res is None:
        return 0.0
    _, rdims = res
    out = 1.0
    for d in rdims:
        out *= d
    lhs_t = _operand_type(comp, op.operands[0]) if op.operands else ""
    lhs = _shape_dims(lhs_t)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contracted = 1.0
    if lhs and cm and cm.group(1):
        _, ldims = lhs
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(ldims):
                contracted *= ldims[ci]
    return 2.0 * out * contracted


def _conv_flops(comp: _Computation, op: _Op) -> float:
    res = _shape_dims(op.result_type)
    rhs = _shape_dims(_operand_type(comp, op.operands[1])) if len(op.operands) > 1 else None
    if res is None or rhs is None:
        return 0.0
    out = 1.0
    for d in res[1]:
        out *= d
    ker = 1.0
    for d in rhs[1][:-1]:  # kernel spatial x in-channels (approx)
        ker *= d
    return 2.0 * out * ker


# HBM-traffic model: only ops that materialize buffers on the TPU target
# count traffic.  Raw elementwise/convert/broadcast/select/compare at the HLO
# top level exist because the CPU backend fuses less than the TPU backend --
# on TPU they fuse into neighbours, so counting them would double-charge
# (verified: with them included, bytes exceed XLA's own estimate by >100x).
_BYTES_OPS = {
    "dot", "convolution", "fusion", "custom-call", "copy", "copy-start",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "reduce", "reduce-window", "select-and-scatter", "sort", "transpose",
    "pad", "concatenate", "slice", "iota", "rng", "cholesky",
    "triangular-solve", "fft",
} | set(COLLECTIVES)


def _fusion_root_op(comps, op: _Op) -> Optional[_Op]:
    cm = re.search(r"calls=%([\w.\-]+)", op.line)
    if not cm:
        return None
    called = comps.get(cm.group(1))
    if called is None or called.root is None:
        return None
    tgt = called.ops.get(called.root)
    hops = 0
    while (
        tgt is not None
        and tgt.opcode in ("bitcast", "copy", "convert", "reshape", "transpose")
        and tgt.operands
        and hops < 4
    ):
        tgt = called.ops.get(tgt.operands[0])
        hops += 1
    return tgt


def _op_traffic(comp, comps, op: _Op, rbytes: int, obytes: int) -> float:
    """HBM traffic model per op (TPU semantics).

    Slicing-style access (dynamic-slice/gather, or fusions rooted in one --
    the pattern scan uses to read one layer's params/cache from the stacked
    buffer) touches only the slice, not the whole buffer.  In-place updates
    (dynamic-update-slice / scatter roots) touch only the update.  Everything
    else: operands read + result written.  Heuristic: a fusion that computes
    on a large operand *before* slicing is undercounted -- rare in practice
    (XLA hoists such compute out of the slice fusion).
    """
    oc = op.opcode
    if oc in ("dynamic-slice", "slice", "gather"):
        return 2.0 * rbytes
    if oc == "dynamic-update-slice":
        upd = _shape_bytes(_operand_type(comp, op.operands[1])) if len(op.operands) > 1 else 0
        return 2.0 * upd
    if oc == "fusion":
        root = _fusion_root_op(comps, op)
        if root is not None:
            small = [
                _shape_bytes(_operand_type(comp, r)) for r in op.operands
            ]
            if root.opcode == "dynamic-update-slice":
                # aliased big buffer: charge non-aliased operands twice
                return 2.0 * sum(b for b in small if b != rbytes)
            if root.opcode in ("dynamic-slice", "gather", "slice"):
                # slice read+write + operands no larger than the slice
                return 2.0 * rbytes + sum(b for b in small if b <= rbytes)
            if root.opcode in ("scatter",):
                # touched rows ~ updates; skip the big aliased table
                return 3.0 * sum(b for b in small if b < rbytes)
    return float(rbytes + obytes)


def parse_hlo_module(text: str) -> HloCosts:
    comps = _split_computations(text)
    entry = comps.get("__entry__")
    costs = HloCosts()
    if entry is None:
        return costs

    # multiplicity propagation (DFS from entry; while bodies multiply)
    mult: Dict[str, float] = defaultdict(float)
    flop_mult: Dict[str, float] = defaultdict(float)  # includes fusion bodies
    stack: List[Tuple[str, float, bool]] = [(entry.name, 1.0, True)]
    seen_pairs = set()
    while stack:
        cname, m, top_level = stack.pop()
        key = (cname, m, top_level)
        if key in seen_pairs or cname not in comps:
            continue
        seen_pairs.add(key)
        comp = comps[cname]
        if top_level:
            mult[cname] += m
        flop_mult[cname] += m
        for op in comp.ops.values():
            if op.opcode == "while":
                bm = re.search(r"body=%([\w.\-]+)", op.line)
                cm = re.search(r"condition=%([\w.\-]+)", op.line)
                trips = _trip_count(comps[cm.group(1)]) if cm and cm.group(1) in comps else 1
                costs.num_while_loops += 1
                if bm and bm.group(1) in comps:
                    stack.append((bm.group(1), m * trips, True))
                if cm and cm.group(1) in comps:
                    stack.append((cm.group(1), m * trips, True))
            elif op.opcode in ("fusion", "reduce", "map", "scatter", "select-and-scatter", "sort", "custom-call", "reduce-window"):
                for ref in _CALL_ATTR_RE.findall(op.line):
                    if ref in comps:
                        stack.append((ref, m, False))
            elif op.opcode in ("call", "conditional"):
                for ref in _CALL_ATTR_RE.findall(op.line) + op.operands:
                    if ref in comps:
                        stack.append((ref, m, True))

    # cost accumulation
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        fm = flop_mult.get(cname, 0.0)
        tm = mult.get(cname, 0.0)
        if fm == 0.0 and tm == 0.0:
            continue
        for op in comp.ops.values():
            if op.opcode == "dot" and fm:
                costs.dot_flops += fm * _dot_flops(comp, op)
            elif op.opcode == "convolution" and fm:
                costs.dot_flops += fm * _conv_flops(comp, op)
            if not tm or op.opcode not in _BYTES_OPS:
                continue
            rbytes = _shape_bytes(op.result_type)
            obytes = sum(
                _shape_bytes(_operand_type(comp, r)) for r in op.operands
            )
            traffic = tm * _op_traffic(comp, comps, op, rbytes, obytes)
            costs.hbm_bytes += traffic
            costs.bytes_by_op[op.opcode] += traffic
            if op.opcode in COLLECTIVES:
                gm = _GROUPS_RE.search(op.line)
                if gm:
                    gsize = int(gm.group(2))
                else:
                    gm2 = _GROUPS_OLD_RE.search(op.line)
                    gsize = len(gm2.group(1).split(",")) if gm2 else 2
                frac = (gsize - 1) / gsize if gsize > 1 else 0.0
                if op.opcode == "all-reduce":
                    wire = 2.0 * rbytes * frac
                elif op.opcode == "all-gather":
                    wire = rbytes * frac
                elif op.opcode == "reduce-scatter":
                    wire = obytes * frac
                elif op.opcode == "all-to-all":
                    wire = rbytes * frac
                else:  # collective-permute
                    wire = rbytes
                costs.collective_tensor_bytes += tm * rbytes
                costs.collective_wire_bytes += tm * wire
                costs.collective_by_type[op.opcode] += tm * wire
                costs.collective_count[op.opcode] += int(tm)
    return costs
