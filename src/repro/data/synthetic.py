"""Dataset generators mirroring the paper's evaluation (Section 5.1).

The paper's synthetic worst-case datasets are exponential(lambda=40) in each
dimension, clipped to [0,1] -- near-identical variance in every dimension, so
REORDER cannot help.  Real-world datasets (SuSy, Songs, ColorHist, ...) are
not redistributable here; ``clustered_dataset`` generates stand-ins with the
same |D|/n and the skewed per-dimension variance profile that makes REORDER
effective (a mixture of tight Gaussian clusters plus low-variance nuisance
dimensions).  ``PAPER_DATASETS`` lists the paper's Table 1 at full size;
``paper_dataset(name, scale)`` lets benchmarks shrink |D| on CPU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

# Table 1 of the paper: name -> (|D|, n, kind)
PAPER_DATASETS: Dict[str, Tuple[int, int, str]] = {
    "CoocTexture": (68_040, 16, "clustered"),
    "LayoutHist": (66_616, 32, "clustered"),
    "ColorHist": (68_040, 32, "clustered"),
    "SuSy": (5_000_000, 18, "clustered"),
    "Songs": (515_345, 90, "clustered"),
    "Syn16D2M": (2_000_000, 16, "exponential"),
    "Syn32D2M": (2_000_000, 32, "exponential"),
    "Syn64D2M": (2_000_000, 64, "exponential"),
}


def exponential_dataset(
    num_points: int, num_dims: int, lam: float = 40.0, seed: int = 0
) -> np.ndarray:
    """Paper Sec. 5.1 synthetic: exponential(lambda=40) per dim, in [0,1]."""
    rng = np.random.default_rng(seed)
    x = rng.exponential(scale=1.0 / lam, size=(num_points, num_dims))
    return np.clip(x, 0.0, 1.0).astype(np.float32)


def uniform_dataset(num_points: int, num_dims: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.random((num_points, num_dims), dtype=np.float32)


def clustered_dataset(
    num_points: int,
    num_dims: int,
    num_clusters: int = 32,
    cluster_std: float = 0.02,
    low_variance_dims: int = 0,
    seed: int = 0,
) -> np.ndarray:
    """Real-world stand-in: Gaussian mixture with optional low-variance dims.

    ``low_variance_dims`` leading dimensions get near-constant values -- the
    Songs-like profile where the first dims carry no filtering power until
    REORDER moves high-variance dims forward (paper Fig. 6b).
    """
    rng = np.random.default_rng(seed)
    centers = rng.random((num_clusters, num_dims))
    which = rng.integers(0, num_clusters, size=num_points)
    pts = centers[which] + rng.normal(0.0, cluster_std, (num_points, num_dims))
    pts = np.clip(pts, 0.0, 1.0).astype(np.float32)
    if low_variance_dims:
        lv = min(low_variance_dims, num_dims)
        base = rng.random(lv)
        pts[:, :lv] = np.clip(
            base[None, :] + rng.normal(0, 1e-3, (num_points, lv)), 0, 1
        ).astype(np.float32)
    return pts


def paper_dataset(name: str, scale: float = 1.0, seed: int = 0) -> np.ndarray:
    """A Table-1 dataset (or stand-in) at ``scale`` x its published |D|."""
    size, dims, kind = PAPER_DATASETS[name]
    n = max(16, int(round(size * scale)))
    if kind == "exponential":
        return exponential_dataset(n, dims, seed=seed)
    low_var = {"Songs": 12}.get(name, 0)  # paper: Songs' first ~12 dims are low-variance
    return clustered_dataset(n, dims, low_variance_dims=low_var, seed=seed)
