from repro.data.synthetic import (  # noqa: F401
    exponential_dataset,
    uniform_dataset,
    clustered_dataset,
    paper_dataset,
    PAPER_DATASETS,
)
