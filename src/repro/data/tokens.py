"""Deterministic synthetic token pipeline for the training examples/tests.

Sharded, resumable iteration: the cursor (step index) lives in the
checkpoint ``extra`` dict, so restart resumes the exact batch sequence
(fault-tolerance invariant tested in tests/test_train.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a given step (resume = same stream)."""
        rng = np.random.default_rng((self.seed, step))
        # zipf-ish marginal: more realistic embedding-gather imbalance
        z = rng.zipf(1.3, size=(self.batch, self.seq))
        tokens = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        return {"tokens": tokens, "labels": tokens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
