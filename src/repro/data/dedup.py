"""Near-duplicate detection over example embeddings -- the paper's self-join
as a first-class framework feature (DESIGN.md #3).

Training pipelines embed examples (any encoder; here a deterministic hashed
n-gram projection so the pipeline is self-contained) and run the distance
self-join with eps as the near-dup radius.  Connected pairs are grouped
greedily and only one representative per group is kept -- the standard
embedding-dedup stage of LM data pipelines, powered by GPU-Join instead of
an LSH approximation (exact within eps).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import SelfJoinConfig, self_join


def hashed_ngram_embed(
    token_ids: np.ndarray, dim: int = 32, n: int = 3, seed: int = 0
) -> np.ndarray:
    """(num_examples, seq) int tokens -> (num_examples, dim) float32 in [0,1].

    Deterministic hashed n-gram count projection, L2-ish normalized then
    squashed into the unit cube (the join's expected domain).
    """
    rng = np.random.default_rng(seed)
    proj = rng.normal(size=(64, dim)).astype(np.float32)
    out = np.zeros((token_ids.shape[0], dim), np.float32)
    for i, row in enumerate(np.asarray(token_ids)):
        acc = np.zeros(dim, np.float32)
        for j in range(len(row) - n + 1):
            h = hash(tuple(int(x) for x in row[j : j + n])) % 64
            acc += proj[h]
        norm = np.linalg.norm(acc)
        if norm > 0:
            acc /= norm
        out[i] = acc
    return ((out + 1.0) * 0.5).astype(np.float32)


@dataclasses.dataclass
class DedupResult:
    keep: np.ndarray            # indices of retained examples
    group_of: np.ndarray        # (N,) group id per example
    num_duplicate_pairs: int
    stats: object               # SelfJoinStats of the underlying join


def find_near_duplicates(
    embeddings: np.ndarray,
    eps: float,
    *,
    config: Optional[SelfJoinConfig] = None,
) -> DedupResult:
    """Group examples whose embeddings are within eps; keep the first of
    each group (greedy union-find over the join's pair output)."""
    n = embeddings.shape[0]
    cfg = config or SelfJoinConfig(
        eps=eps, k=min(6, embeddings.shape[1]), tile_size=32
    )
    res = self_join(embeddings, cfg, return_pairs=True)

    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    dup_pairs = 0
    for a, b in res.pairs:
        if a == b:
            continue
        dup_pairs += 1
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)

    group_of = np.array([find(i) for i in range(n)])
    keep = np.unique(group_of)
    return DedupResult(
        keep=keep, group_of=group_of,
        num_duplicate_pairs=dup_pairs // 2, stats=res.stats,
    )


def dedup_token_dataset(
    examples: np.ndarray, eps: float = 0.05, embed_dim: int = 16
) -> np.ndarray:
    """Convenience: embed token examples, join, return deduped examples."""
    emb = hashed_ngram_embed(examples, dim=embed_dim)
    res = find_near_duplicates(emb, eps)
    return examples[res.keep]
