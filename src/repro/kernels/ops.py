"""Jitted wrappers around the tile-distance evaluation.

Four interchangeable backends with one contract (two per execution tier,
DESIGN.md #9):

  * ``backend="pallas"``    -- the indexed-tier TPU kernel
    (``distance_tile.py``, SHORTC dimension-blocked), run in interpret mode
    on CPU; the deployment path on real TPUs.
  * ``backend="jnp"``       -- a vectorized jnp implementation of the same
    blocked algorithm (used for CPU-speed benchmarking and as the XLA
    fallback).
  * ``backend="dense"``     -- the dense-tier TPU kernel (``dense_tile.py``):
    no SHORTC branching, squared distances by the clamped matmul identity
    ``max(|a|^2 + |b|^2 - 2 a.b^T, 0)`` (``ref.matmul_sqdist``).
  * ``backend="dense_jnp"`` -- the XLA twin of the dense kernel.

``backend_name(tier, use_pallas)`` maps an execution tier to its backend
string; the dense backends ignore ``shortc`` and report 0 skipped blocks.

Compilation-caching contract (DESIGN.md #1.5): the candidate pair list is
evaluated in fixed-size, zero-padded chunks, and ``eps`` is always a traced
scalar, so XLA compiles exactly one program per (backend, chunk shape,
dim_block) -- never one per dataset, per chunk, or per eps value.  The
building blocks here are traceable (``eval_tile_pairs``,
``make_tiles_device``) so ``repro.core.engine`` can fuse them with its
scatter/compaction epilogues into single device programs; the jitted
``tile_counts`` / ``tile_mask`` entry points below remain the standalone
host-facing API.

``make_tiles`` re-lays the grid-sorted points into the (num_tiles, T, n_pad)
layout the kernel consumes; it is a vectorized gather (host numpy) with a
device twin ``make_tiles_device`` that runs inside jit.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dense_tile, distance_tile
from repro.kernels import ref as ref_mod

BACKENDS = ("pallas", "jnp", "dense", "dense_jnp")


def backend_name(execution: str, use_pallas: bool) -> str:
    """Backend string for an execution tier (``"indexed"`` | ``"dense"``)."""
    if execution == "dense":
        return "dense" if use_pallas else "dense_jnp"
    return "pallas" if use_pallas else "jnp"


def make_tiles(
    pts_sorted: np.ndarray,
    tile_start: np.ndarray,
    tile_len: np.ndarray,
    tile_size: int,
    dim_block: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Re-lay points into (num_tiles, T, n_pad) with zero padding.

    Zero padding in both the point axis (tail tiles) and the dimension axis
    (n -> n_pad) is distance-neutral; validity is enforced via ``tile_len``.
    Vectorized gather -- no per-tile host loop.
    """
    num_tiles = tile_start.shape[0]
    n_pts, n = pts_sorted.shape
    n_pad = ((n + dim_block - 1) // dim_block) * dim_block
    if num_tiles == 0:
        return (
            np.zeros((1, tile_size, n_pad), dtype=np.float32),
            tile_len.astype(np.int32),
        )
    lane = np.arange(tile_size, dtype=np.int64)
    idx = tile_start.astype(np.int64)[:, None] + lane[None, :]   # (num_tiles, T)
    valid = lane[None, :] < tile_len.astype(np.int64)[:, None]
    gathered = pts_sorted[np.minimum(idx, max(n_pts - 1, 0))]    # (num_tiles, T, n)
    tiles = np.zeros((num_tiles, tile_size, n_pad), dtype=np.float32)
    tiles[:, :, :n] = np.where(valid[:, :, None], gathered, 0.0)
    return tiles, tile_len.astype(np.int32)


@functools.partial(jax.jit, static_argnames=("tile_size", "dim_block"))
def make_tiles_device(
    pts_sorted: jax.Array,    # (N, n) f32
    tile_start: jax.Array,    # (num_tiles,) int32
    tile_len: jax.Array,      # (num_tiles,) int32
    *,
    tile_size: int,
    dim_block: int,
) -> jax.Array:
    """Device twin of ``make_tiles``: one gather + pad, inside jit.

    Returns (max(num_tiles,1), T, n_pad) f32, resident on device.  Out-of-
    range gathers (tail-tile padding lanes) are clamped and then zeroed by
    the validity mask, so the result is bit-identical to the host layout.
    """
    num_tiles = tile_start.shape[0]
    n = pts_sorted.shape[1]
    n_pad = ((n + dim_block - 1) // dim_block) * dim_block
    if num_tiles == 0:
        return jnp.zeros((1, tile_size, n_pad), jnp.float32)
    lane = jnp.arange(tile_size, dtype=jnp.int32)
    idx = tile_start[:, None] + lane[None, :]                    # (num_tiles, T)
    valid = lane[None, :] < tile_len[:, None]
    gathered = pts_sorted[idx]            # OOB rows clamp (jit gather) then mask
    tiles = jnp.where(valid[:, :, None], gathered, 0.0)
    if n_pad != n:
        tiles = jnp.pad(tiles, ((0, 0), (0, 0), (0, n_pad - n)))
    return tiles


def eval_tile_pairs(
    tiles_pts,
    tile_len,
    pair_a,
    pair_b,
    eps,
    *,
    dim_block: int,
    shortc: bool = True,
    backend: str = "jnp",
    return_mask: bool = False,
    interpret: bool = True,
):
    """Traceable tile-pair evaluation shared by both backends.

    ``eps`` may be a python float or a traced f32 scalar.  Returns
    ``(counts (P,T) int32, skipped (P,) int32[, mask (P,T,T) int8])``.
    Safe to call inside an enclosing ``jax.jit`` (the engine does).
    """
    if backend == "pallas":
        res = distance_tile.tile_pair_distance(
            tiles_pts, tile_len, pair_a, pair_b,
            eps=eps, dim_block=dim_block, interpret=interpret,
            return_mask=return_mask,
        )
        counts, skipped = res[0], res[1][:, 0]
        if not shortc:  # kernel always short-circuits; zero the stat
            skipped = jnp.zeros_like(skipped)
        return (counts, skipped, res[2]) if return_mask else (counts, skipped)
    if backend == "dense":  # dense tier: no SHORTC, `shortc` is ignored
        res = dense_tile.dense_tile_distance(
            tiles_pts, tile_len, pair_a, pair_b,
            eps=eps, dim_block=dim_block, interpret=interpret,
            return_mask=return_mask,
        )
        counts = res[0]
        skipped = jnp.zeros((pair_a.shape[0],), jnp.int32)
        return (counts, skipped, res[1]) if return_mask else (counts, skipped)
    if backend == "dense_jnp":
        return _eval_dense_jnp(
            tiles_pts, tile_len, pair_a, pair_b, eps, return_mask=return_mask
        )
    if backend != "jnp":
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    return _eval_jnp(
        tiles_pts, tile_len, pair_a, pair_b, eps,
        dim_block=dim_block, shortc=shortc, return_mask=return_mask,
    )


def _eval_dense_jnp(tiles_pts, tile_len, pair_a, pair_b, eps, *, return_mask):
    """XLA twin of the dense kernel: clamped matmul identity, no blocking."""
    t = tiles_pts.shape[1]
    a = tiles_pts[pair_a]                      # (P, T, n_pad)
    b = tiles_pts[pair_b]
    d2 = ref_mod.matmul_sqdist(a, b)           # (P, T, T), clamped at 0
    la = tile_len[pair_a]
    lb = tile_len[pair_b]
    rows = jnp.arange(t, dtype=jnp.int32)
    valid = (rows[None, :, None] < la[:, None, None]) & (
        rows[None, None, :] < lb[:, None, None]
    )
    within = (d2 <= jnp.asarray(eps, jnp.float32) ** 2) & valid
    counts = within.sum(axis=2, dtype=jnp.int32)
    skipped = jnp.zeros((pair_a.shape[0],), jnp.int32)
    if return_mask:
        return counts, skipped, within.astype(jnp.int8)
    return counts, skipped


def _eval_jnp(
    tiles_pts, tile_len, pair_a, pair_b, eps, *, dim_block, shortc, return_mask
):
    """Pure-jnp blocked evaluation (traceable; ``eps`` may be traced)."""
    t = tiles_pts.shape[1]
    n_pad = tiles_pts.shape[2]
    p = pair_a.shape[0]
    a = tiles_pts[pair_a]                      # (P, T, n_pad)
    b = tiles_pts[pair_b]
    la = tile_len[pair_a]
    lb = tile_len[pair_b]
    rows = jnp.arange(t, dtype=jnp.int32)
    valid = (rows[None, :, None] < la[:, None, None]) & (
        rows[None, None, :] < lb[:, None, None]
    )
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    neg_large = jnp.float32(3.0e38)

    if not shortc:
        na = jnp.einsum("ptn,ptn->pt", a, a)
        nb_ = jnp.einsum("ptn,ptn->pt", b, b)
        d2 = (
            na[:, :, None]
            + nb_[:, None, :]
            - 2.0 * jnp.einsum("pin,pjn->pij", a, b)
        )
        skipped = jnp.zeros((p,), jnp.int32)
    else:
        nb_blocks = n_pad // dim_block
        a_blk = a.reshape(p, t, nb_blocks, dim_block).transpose(2, 0, 1, 3)
        b_blk = b.reshape(p, t, nb_blocks, dim_block).transpose(2, 0, 1, 3)

        def body(carry, xs):
            d2, done, skipped = carry
            ab, bb = xs
            na = jnp.einsum("ptn,ptn->pt", ab, ab)
            nbv = jnp.einsum("ptn,ptn->pt", bb, bb)
            contrib = (
                na[:, :, None]
                + nbv[:, None, :]
                - 2.0 * jnp.einsum("pin,pjn->pij", ab, bb)
            )
            skipped = skipped + done.astype(jnp.int32)
            d2 = jnp.where(done[:, None, None], d2, d2 + contrib)
            d2_masked = jnp.where(valid, d2, neg_large)
            done = done | (jnp.min(d2_masked, axis=(1, 2)) > eps2)
            return (d2, done, skipped), None

        init = (
            jnp.zeros((p, t, t), jnp.float32),
            jnp.zeros((p,), jnp.bool_),
            jnp.zeros((p,), jnp.int32),
        )
        (d2, _, skipped), _ = jax.lax.scan(body, init, (a_blk, b_blk))

    within = (d2 <= eps2) & valid
    counts = within.sum(axis=2, dtype=jnp.int32)
    if return_mask:
        return counts, skipped, within.astype(jnp.int8)
    return counts, skipped


@functools.partial(
    jax.jit,
    static_argnames=("dim_block", "shortc", "backend", "return_mask", "interpret"),
)
def _eval_chunk(
    tiles_pts, tile_len, pair_a, pair_b, eps,
    *, dim_block, shortc, backend, return_mask, interpret
):
    return eval_tile_pairs(
        tiles_pts, tile_len, pair_a, pair_b, eps,
        dim_block=dim_block, shortc=shortc, backend=backend,
        return_mask=return_mask, interpret=interpret,
    )


def tile_counts(
    tiles_pts: np.ndarray,
    tile_len: np.ndarray,
    pair_a: np.ndarray,
    pair_b: np.ndarray,
    *,
    eps: float,
    dim_block: int = 32,
    shortc: bool = True,
    backend: str = "jnp",
    chunk: int = 4096,
    interpret: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Counts (P, T) and SHORTC-skipped block counts (P,) for all pairs."""
    out_counts, out_skipped = [], []
    tiles_j = jnp.asarray(tiles_pts)
    len_j = jnp.asarray(tile_len)
    for c, pa, pb, real in _chunks(pair_a, pair_b, chunk):
        counts, skipped = _eval_chunk(
            tiles_j, len_j, pa, pb, eps,
            dim_block=dim_block, shortc=shortc, backend=backend,
            return_mask=False, interpret=interpret,
        )
        out_counts.append(np.asarray(counts)[:real])
        out_skipped.append(np.asarray(skipped)[:real])
    if not out_counts:
        t = tiles_pts.shape[1]
        return np.zeros((0, t), np.int32), np.zeros((0,), np.int32)
    return np.concatenate(out_counts), np.concatenate(out_skipped)


def tile_mask(
    tiles_pts: np.ndarray,
    tile_len: np.ndarray,
    pair_a: np.ndarray,
    pair_b: np.ndarray,
    *,
    eps: float,
    dim_block: int = 32,
    backend: str = "jnp",
    chunk: int = 512,
    interpret: bool = True,
):
    """Yield (pair_slice_start, mask (Pc, T, T) int8 numpy) per chunk."""
    done = 0
    tiles_j = jnp.asarray(tiles_pts)
    len_j = jnp.asarray(tile_len)
    for c, pa, pb, real in _chunks(pair_a, pair_b, chunk):
        _, _, mask = _eval_chunk(
            tiles_j, len_j, pa, pb, eps,
            dim_block=dim_block, shortc=True, backend=backend,
            return_mask=True, interpret=interpret,
        )
        yield done, np.asarray(mask)[:real]
        done += real


def _chunks(pair_a: np.ndarray, pair_b: np.ndarray, chunk: int):
    """Fixed-size, zero-padded chunks (single XLA program per layout)."""
    p = pair_a.shape[0]
    for s in range(0, p, chunk):
        pa = pair_a[s : s + chunk]
        pb = pair_b[s : s + chunk]
        real = pa.shape[0]
        if real < chunk:
            pa = np.pad(pa, (0, chunk - real))
            pb = np.pad(pb, (0, chunk - real))
        yield s, jnp.asarray(pa, jnp.int32), jnp.asarray(pb, jnp.int32), real
