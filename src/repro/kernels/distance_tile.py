"""Pallas TPU kernel: eps-neighbourhood evaluation of candidate tile pairs.

This is the compute hot-spot of the paper (the CUDA self-join kernel,
Alg. 1 lines 11-19) re-thought for the TPU (DESIGN.md #1):

  * each grid program evaluates one candidate tile pair (A, B) of
    ``tile_size`` points each, as the MXU-friendly contraction
    ``d2 = |a|^2 + |b|^2 - 2 a.b^T``;
  * the n coordinate dimensions are processed in ``dim_block``-wide blocks,
    highest variance first (REORDER).  A tile pair short-circuits -- the TPU
    analogue of SHORTC -- when the partial d2 minimum over all valid lanes
    already exceeds eps^2: every remaining block can only grow d2, so all
    pairs are decided and the remaining MXU work is skipped via ``pl.when``;
  * tiles are fetched from HBM into VMEM by BlockSpec index maps driven by
    scalar-prefetched tile indices (the flat candidate work list produced by
    ``repro.core.grid.build_tile_plan``);
  * ``eps`` is a *runtime* scalar, prefetched into SMEM alongside the tile
    indices (DESIGN.md #1.5): one compiled program serves every eps value,
    which is what lets ``SelfJoinEngine.query`` sweep eps without recompiling.

Grid: ``(P, NB)`` -- P candidate pairs x NB dimension blocks; the dim-block
axis is minor, so VMEM scratch carries the partial d2 across blocks of the
same pair and is reset at block 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_LARGE = 3.0e38  # python float: becomes an inline literal, not a captured const


def _kernel(
    a_idx_ref,      # (P,) int32  scalar prefetch: A tile index per pair
    b_idx_ref,      # (P,) int32  scalar prefetch: B tile index per pair
    tile_len_ref,   # (num_tiles,) int32 scalar prefetch: valid points per tile
    eps2_ref,       # (1,) f32    scalar prefetch: runtime eps^2
    a_ref,          # (1, T, DB) f32 VMEM: current dim block of the A tile
    b_ref,          # (1, T, DB) f32 VMEM: current dim block of the B tile
    counts_ref,     # (1, T) int32 out: per-A-point neighbour count
    skipped_ref,    # (1, 1) int32 out: dim blocks skipped by SHORTC
    d2_ref,         # (T, T) f32 VMEM scratch: partial squared distances
    flags_ref,      # (2,) int32 SMEM scratch: [done, blocks_computed]
    *,
    num_blocks: int,
    tile_size: int,
    out_mask_ref=None,  # optional (1, T, T) int8 out (pairs mode)
):
    p = pl.program_id(0)
    j = pl.program_id(1)
    t = tile_size
    eps2 = eps2_ref[0]

    @pl.when(j == 0)
    def _init():
        d2_ref[:, :] = jnp.zeros((t, t), jnp.float32)
        flags_ref[0] = 0
        flags_ref[1] = 0

    la = tile_len_ref[a_idx_ref[p]]
    lb = tile_len_ref[b_idx_ref[p]]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    valid = (rows < la) & (cols < lb)

    @pl.when(flags_ref[0] == 0)
    def _accumulate():
        a = a_ref[0]                                   # (T, DB)
        b = b_ref[0]
        na = jnp.sum(a * a, axis=1, keepdims=True)     # (T, 1)
        nb = jnp.sum(b * b, axis=1, keepdims=True)     # (T, 1)
        prod = jax.lax.dot_general(
            a,
            b,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # (T, T) = a . b^T
        d2_ref[:, :] = d2_ref[:, :] + na + nb.T - 2.0 * prod
        flags_ref[1] = flags_ref[1] + 1
        # SHORTC (tile granularity): if even the closest still-valid pair
        # already exceeds eps^2, every pair is decided -- skip later blocks.
        d2_masked = jnp.where(valid, d2_ref[:, :], _NEG_LARGE)
        flags_ref[0] = jnp.where(jnp.min(d2_masked) > eps2, 1, 0).astype(jnp.int32)

    @pl.when(j == num_blocks - 1)
    def _finalize():
        within = (d2_ref[:, :] <= eps2) & valid
        counts_ref[0, :] = jnp.sum(within.astype(jnp.int32), axis=1)
        skipped_ref[0, 0] = num_blocks - flags_ref[1]
        if out_mask_ref is not None:
            out_mask_ref[0, :, :] = within.astype(jnp.int8)


def _mask_kernel(*refs, num_blocks, tile_size):
    (a_idx, b_idx, tl, eps2, a, b, counts, skipped, mask, d2, flags) = refs
    _kernel(
        a_idx, b_idx, tl, eps2, a, b, counts, skipped, d2, flags,
        num_blocks=num_blocks, tile_size=tile_size,
        out_mask_ref=mask,
    )


@functools.partial(
    jax.jit,
    static_argnames=("dim_block", "interpret", "return_mask"),
)
def tile_pair_distance(
    tiles_pts: jax.Array,   # (num_tiles, T, n_pad) f32; n_pad % dim_block == 0
    tile_len: jax.Array,    # (num_tiles,) int32
    pair_a: jax.Array,      # (P,) int32
    pair_b: jax.Array,      # (P,) int32
    *,
    eps: float,
    dim_block: int = 32,
    interpret: bool = True,
    return_mask: bool = False,
):
    """Evaluate all candidate tile pairs.

    ``eps`` may be a python float or a traced f32 scalar; it is forwarded to
    the kernel as a scalar-prefetch operand, so distinct eps values share one
    executable.  Returns ``(counts (P,T) int32, skipped (P,1) int32)`` and,
    when ``return_mask``, also the per-pair boolean mask ``(P, T, T) int8``.
    """
    num_tiles, t, n_pad = tiles_pts.shape
    if n_pad % dim_block:
        raise ValueError(f"n_pad={n_pad} not a multiple of dim_block={dim_block}")
    nb = n_pad // dim_block
    p = pair_a.shape[0]
    eps2 = (jnp.asarray(eps, jnp.float32) ** 2).reshape(1)

    tile_spec_a = pl.BlockSpec(
        (1, t, dim_block), lambda pp, jj, a_idx, b_idx, tl, e2: (a_idx[pp], 0, jj)
    )
    tile_spec_b = pl.BlockSpec(
        (1, t, dim_block), lambda pp, jj, a_idx, b_idx, tl, e2: (b_idx[pp], 0, jj)
    )
    counts_spec = pl.BlockSpec((1, t), lambda pp, jj, *_: (pp, 0))
    skip_spec = pl.BlockSpec((1, 1), lambda pp, jj, *_: (pp, 0))

    out_shapes = [
        jax.ShapeDtypeStruct((p, t), jnp.int32),
        jax.ShapeDtypeStruct((p, 1), jnp.int32),
    ]
    out_specs = [counts_spec, skip_spec]
    if return_mask:
        out_shapes.append(jax.ShapeDtypeStruct((p, t, t), jnp.int8))
        out_specs.append(pl.BlockSpec((1, t, t), lambda pp, jj, *_: (pp, 0, 0)))
        body = functools.partial(_mask_kernel, num_blocks=nb, tile_size=t)
    else:
        body = functools.partial(_kernel, num_blocks=nb, tile_size=t)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(p, nb),
        in_specs=[tile_spec_a, tile_spec_b],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((t, t), jnp.float32),
            pltpu.SMEM((2,), jnp.int32),
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(pair_a, pair_b, tile_len, eps2, tiles_pts, tiles_pts)
