"""Pure-jnp oracles for the tile distance kernels.

The oracle evaluates candidate tile pairs with the *direct* (a-b)^2
formulation in float32 -- intentionally a different numeric path from the
kernel's matmul form so tests exercise both (see DESIGN.md #6; exactness
tests quantize coordinates so both forms are exact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_sqdist(a: jax.Array, b: jax.Array) -> jax.Array:
    """Clamped matmul-form squared distances (the dense tier's identity).

    ``a``: (..., Ta, n), ``b``: (..., Tb, n) -> (..., Ta, Tb) float32 with
    ``d2 = max(|a|^2 + |b|^2 - 2 a.b^T, 0)``.  The clamp is load-bearing on
    arbitrary fp32 data: rounding of the three-term form can dip a true-zero
    distance slightly negative, which would silently survive an ``<= eps^2``
    test but corrupt any downstream sqrt.  On 1/64-quantized coordinates the
    form is exact and the clamp is a no-op (DESIGN.md #6).
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    na = jnp.sum(a * a, axis=-1)[..., :, None]
    nb = jnp.sum(b * b, axis=-1)[..., None, :]
    prod = jnp.einsum("...in,...jn->...ij", a, b)
    return jnp.maximum(na + nb - 2.0 * prod, 0.0)


def direct_sqdist(a: jax.Array, b: jax.Array) -> jax.Array:
    """Direct-form squared distances ``|a - b|^2``, (..., Ta, Tb) float32.

    The numerically independent oracle for ``matmul_sqdist`` (different
    rounding path; never negative by construction).
    """
    diff = a.astype(jnp.float32)[..., :, None, :] - b.astype(jnp.float32)[..., None, :, :]
    return jnp.einsum("...ijn,...ijn->...ij", diff, diff)


def ref_tile_counts(
    tiles_pts: jax.Array,   # (num_tiles, T, n) float32, zero-padded
    tile_len: jax.Array,    # (num_tiles,) int32
    pair_a: jax.Array,      # (P,) int32
    pair_b: jax.Array,      # (P,) int32
    eps: float,
) -> jax.Array:
    """Per-(pair, a-point) neighbour counts, (P, T) int32."""
    mask = ref_tile_mask(tiles_pts, tile_len, pair_a, pair_b, eps)
    return mask.sum(axis=2, dtype=jnp.int32)


def ref_attention(q, k, v, *, causal=True, scale=None):
    """Dense softmax attention oracle. q: (BH, Sq, dh), k/v: (BH, Sk, dh/dv)."""
    if scale is None:
        scale = float(q.shape[-1]) ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[1], s.shape[2]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1.0e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


def ref_tile_mask(
    tiles_pts: jax.Array,
    tile_len: jax.Array,
    pair_a: jax.Array,
    pair_b: jax.Array,
    eps: float,
) -> jax.Array:
    """Boolean (P, T, T): pair (i, j) within eps and both lanes valid."""
    t = tiles_pts.shape[1]
    a = tiles_pts[pair_a]            # (P, T, n)
    b = tiles_pts[pair_b]
    diff = a[:, :, None, :] - b[:, None, :, :]
    d2 = jnp.einsum("pijn,pijn->pij", diff, diff)
    la = tile_len[pair_a]            # (P,)
    lb = tile_len[pair_b]
    rows = jnp.arange(t, dtype=jnp.int32)
    valid = (rows[None, :, None] < la[:, None, None]) & (
        rows[None, None, :] < lb[:, None, None]
    )
    return (d2 <= jnp.float32(eps) ** 2) & valid
