"""Pallas TPU flash attention (forward) -- the fix for the prefill cells.

EXPERIMENTS.md #Roofline: the pure-JAX online-softmax attention materialises
(B, H, qc, kc) fp32 score chunks between fusions, making every prefill_32k
cell memory-bound.  This kernel keeps the score tile, the running max/sum
and the output accumulator in VMEM scratch; only Q/K/V tiles stream in and
the final output streams out -- per-tile HBM traffic drops from
O(qc*kc) fp32 to O((qc+kc)*dh) bf16.

Grid: (B*KV*G, nq, nk), nk minor so scratch carries across k-tiles of one
q-tile.  Causal masking is positional; strictly-above-diagonal k-tiles skip
their compute via pl.when (the DMA still runs -- Mosaic cannot skip it, but
MXU work does not).

Forward-only by design: the backward runs the jnp path (training uses
flash_remat recomputation); serving/prefill is where this kernel lands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            q_chunk, k_chunk, num_k, scale, causal):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[:, :] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:, :] = jnp.zeros_like(l_ref)
        acc_ref[:, :] = jnp.zeros_like(acc_ref)

    q_start = i * q_chunk
    k_start = j * k_chunk
    # strictly above the causal diagonal: no valid pair in this tile
    live = (not causal) or (k_start <= q_start + q_chunk - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)               # (qc, dh)
        k = k_ref[0].astype(jnp.float32)               # (kc, dh)
        v = v_ref[0].astype(jnp.float32)               # (kc, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # (qc, kc)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[:, :]                            # (qc, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, :] = l_ref[:, :] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[:, :] = acc_ref[:, :] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, :] = m_new

    @pl.when(j == num_k - 1)
    def _finalize():
        o_ref[0] = (
            acc_ref[:, :] / jnp.maximum(l_ref[:, :], 1e-37)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_chunk", "k_chunk", "scale", "interpret"),
)
def flash_attention(
    q: jax.Array,      # (BH, Sq, dh)
    k: jax.Array,      # (BH, Sk, dh)
    v: jax.Array,      # (BH, Sk, dv)
    *,
    causal: bool = True,
    q_chunk: int = 512,
    k_chunk: int = 512,
    scale: float | None = None,
    interpret: bool = True,
):
    bh, sq, dh = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    if scale is None:
        scale = float(dh) ** -0.5
    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    if sq % qc or sk % kc:
        raise ValueError(f"seq lens ({sq},{sk}) must divide chunks ({qc},{kc})")
    nq, nk = sq // qc, sk // kc

    body = functools.partial(
        _kernel, q_chunk=qc, k_chunk=kc, num_k=nk, scale=scale, causal=causal
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kc, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kc, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, dv), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((qc, 1), jnp.float32),
            pltpu.VMEM((qc, 1), jnp.float32),
            pltpu.VMEM((qc, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        interpret=interpret,
    )(q, k, v)
