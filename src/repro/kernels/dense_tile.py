"""Pallas TPU kernel: dense (unfiltered) tile-pair distance evaluation.

The MXU half of the hybrid dense/indexed execution tier (DESIGN.md #9).
Where ``distance_tile.py`` evaluates a *grid-filtered* candidate list with
SHORTC short-circuiting, this kernel evaluates an arbitrary (typically the
full cross-product) tile-pair list as straight-line batched matmul work:

  * each grid program evaluates one (A, B) tile pair of ``tile_size`` points
    as ``d2 = max(|a|^2 + |b|^2 - 2 a.b^T, 0)`` -- the clamped matmul
    identity (``kernels/ref.matmul_sqdist``).  The clamp matters on
    arbitrary fp32 data, where rounding of the three-term form can dip a
    true-zero distance slightly negative;
  * the n coordinate dimensions stream through in ``dim_block``-wide blocks
    with a VMEM accumulator, exactly like the indexed kernel -- but with NO
    short-circuit branch: in the regime where the dense tier wins (the grid
    has lost its filtering power, ``stats.candidate_filter_ratio`` -> 1)
    SHORTC almost never fires, and dropping the per-block min-reduction and
    SMEM flag traffic keeps the MXU pipeline saturated;
  * ``eps`` is a runtime scalar, prefetched into SMEM alongside the tile
    indices and lengths (same contract as ``distance_tile.py``): one
    compiled program serves every eps value, which is what lets the serving
    tier's kNN eps-expansion loop stay on warm executables.

Grid: ``(P, NB)`` -- P tile pairs x NB dimension blocks, dim-block axis
minor so the partial-d2 scratch carries across blocks of one pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    a_idx_ref,      # (P,) int32  scalar prefetch: A tile index per pair
    b_idx_ref,      # (P,) int32  scalar prefetch: B tile index per pair
    tile_len_ref,   # (num_tiles,) int32 scalar prefetch: valid points per tile
    eps2_ref,       # (1,) f32    scalar prefetch: runtime eps^2
    a_ref,          # (1, T, DB) f32 VMEM: current dim block of the A tile
    b_ref,          # (1, T, DB) f32 VMEM: current dim block of the B tile
    counts_ref,     # (1, T) int32 out: per-A-point neighbour count
    d2_ref,         # (T, T) f32 VMEM scratch: partial squared distances
    *,
    num_blocks: int,
    tile_size: int,
    out_mask_ref=None,  # optional (1, T, T) int8 out (pairs mode)
):
    p = pl.program_id(0)
    j = pl.program_id(1)
    t = tile_size

    @pl.when(j == 0)
    def _init():
        d2_ref[:, :] = jnp.zeros((t, t), jnp.float32)

    a = a_ref[0]                                   # (T, DB)
    b = b_ref[0]
    na = jnp.sum(a * a, axis=1, keepdims=True)     # (T, 1)
    nb = jnp.sum(b * b, axis=1, keepdims=True)     # (T, 1)
    prod = jax.lax.dot_general(
        a,
        b,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (T, T) = a . b^T
    d2_ref[:, :] = d2_ref[:, :] + na + nb.T - 2.0 * prod

    @pl.when(j == num_blocks - 1)
    def _finalize():
        la = tile_len_ref[a_idx_ref[p]]
        lb = tile_len_ref[b_idx_ref[p]]
        rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        valid = (rows < la) & (cols < lb)
        d2 = jnp.maximum(d2_ref[:, :], 0.0)        # clamp the matmul identity
        within = (d2 <= eps2_ref[0]) & valid
        counts_ref[0, :] = jnp.sum(within.astype(jnp.int32), axis=1)
        if out_mask_ref is not None:
            out_mask_ref[0, :, :] = within.astype(jnp.int8)


def _mask_kernel(*refs, num_blocks, tile_size):
    (a_idx, b_idx, tl, eps2, a, b, counts, mask, d2) = refs
    _kernel(
        a_idx, b_idx, tl, eps2, a, b, counts, d2,
        num_blocks=num_blocks, tile_size=tile_size,
        out_mask_ref=mask,
    )


@functools.partial(
    jax.jit,
    static_argnames=("dim_block", "interpret", "return_mask"),
)
def dense_tile_distance(
    tiles_pts: jax.Array,   # (num_tiles, T, n_pad) f32; n_pad % dim_block == 0
    tile_len: jax.Array,    # (num_tiles,) int32
    pair_a: jax.Array,      # (P,) int32
    pair_b: jax.Array,      # (P,) int32
    *,
    eps: float,
    dim_block: int = 32,
    interpret: bool = True,
    return_mask: bool = False,
):
    """Evaluate every listed tile pair densely (no SHORTC, clamped identity).

    Same calling convention as ``distance_tile.tile_pair_distance`` so
    ``kernels/ops.eval_tile_pairs`` can dispatch on ``backend=`` alone;
    ``eps`` may be a python float or a traced f32 scalar (scalar-prefetch
    operand -- distinct eps values share one executable).  Returns
    ``counts (P, T) int32`` and, when ``return_mask``, also the per-pair
    hit mask ``(P, T, T) int8``.
    """
    num_tiles, t, n_pad = tiles_pts.shape
    if n_pad % dim_block:
        raise ValueError(f"n_pad={n_pad} not a multiple of dim_block={dim_block}")
    nb = n_pad // dim_block
    p = pair_a.shape[0]
    eps2 = (jnp.asarray(eps, jnp.float32) ** 2).reshape(1)

    tile_spec_a = pl.BlockSpec(
        (1, t, dim_block), lambda pp, jj, a_idx, b_idx, tl, e2: (a_idx[pp], 0, jj)
    )
    tile_spec_b = pl.BlockSpec(
        (1, t, dim_block), lambda pp, jj, a_idx, b_idx, tl, e2: (b_idx[pp], 0, jj)
    )
    counts_spec = pl.BlockSpec((1, t), lambda pp, jj, *_: (pp, 0))

    out_shapes = [jax.ShapeDtypeStruct((p, t), jnp.int32)]
    out_specs = [counts_spec]
    if return_mask:
        out_shapes.append(jax.ShapeDtypeStruct((p, t, t), jnp.int8))
        out_specs.append(pl.BlockSpec((1, t, t), lambda pp, jj, *_: (pp, 0, 0)))
        body = functools.partial(_mask_kernel, num_blocks=nb, tile_size=t)
    else:
        body = functools.partial(_kernel, num_blocks=nb, tile_size=t)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(p, nb),
        in_specs=[tile_spec_a, tile_spec_b],
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((t, t), jnp.float32)],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(pair_a, pair_b, tile_len, eps2, tiles_pts, tiles_pts)
