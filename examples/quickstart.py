"""Quickstart: the paper's self-join on a worst-case synthetic dataset.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SelfJoinConfig, self_join, select_k
from repro.data import exponential_dataset

# Syn16D (paper Sec. 5.1) at CPU scale: exponential(lambda=40), worst case
# for REORDER because every dimension has the same variance.
D = exponential_dataset(num_points=20_000, num_dims=16, seed=0)
eps = 0.05

# pick k with the paper's memory-op model (Sec. 5.6)
k = select_k(D, eps, ks=[2, 3, 4, 6, 8])
print(f"selected k={k} (paper uses k=6 throughout)")

cfg = SelfJoinConfig(eps=eps, k=k, reorder=True, sortidu=True, shortc=True)
res = self_join(D, cfg)

print(f"|D|={res.stats.num_points}  n={res.stats.num_dims}  eps={eps}")
print(f"|R| (ordered pairs incl. self) = {res.stats.num_results}")
print(f"selectivity S_D = {res.stats.selectivity:.2f}   (paper Eq. 1)")
print(f"non-empty grid cells |G| = {res.stats.num_nonempty_cells}")
print(f"tile pairs evaluated = {res.stats.num_tile_pairs_evaluated} "
      f"of {res.stats.num_tile_pairs_total} (SORTIDU pruned the rest)")
print(f"SHORTC skipped {res.stats.dim_blocks_skipped}/{res.stats.dim_blocks_total} dim blocks")

# spot check against brute force on a subset
from repro.core.brute import brute_counts
sub = D[:2000]
assert np.array_equal(self_join(sub, cfg).counts, brute_counts(sub, eps))
print("verified against brute force on a 2k subset.")
