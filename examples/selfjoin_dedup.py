"""The paper's technique as a framework feature: near-duplicate detection
in an LM data pipeline via the distance self-join (DESIGN.md #3).

    PYTHONPATH=src python examples/selfjoin_dedup.py
"""
import numpy as np

from repro.data.dedup import find_near_duplicates, hashed_ngram_embed

rng = np.random.default_rng(0)

# a synthetic "web scrape": 500 documents, 60 of which are near-copies
docs = rng.integers(0, 5000, size=(500, 128))
copies = docs[rng.integers(0, 100, size=60)].copy()
mask = rng.random(copies.shape) < 0.02          # 2% token noise
copies[mask] += 1
corpus = np.concatenate([docs, copies])

emb = hashed_ngram_embed(corpus, dim=24)
# near-dup radius: planted copies land below ~0.17, unrelated docs above ~0.23
res = find_near_duplicates(emb, eps=0.2)

print(f"corpus size            : {corpus.shape[0]}")
print(f"near-duplicate pairs   : {res.num_duplicate_pairs}")
print(f"kept after dedup       : {len(res.keep)}")
print(f"join candidates checked: {res.stats.num_candidates} "
      f"(brute force would be {corpus.shape[0] ** 2})")
print(f"selectivity S_D        : {res.stats.selectivity:.3f}")
