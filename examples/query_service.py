"""Serve online similarity queries from a persistent device-resident index.

    PYTHONPATH=src python examples/query_service.py

The serving tier (DESIGN.md #8, #10) on synthetic data: build a
``SimilarityIndex`` once (REORDER + auto-k + grid + device tiles), persist
it, "restart" by loading it back, drive a mixed request stream of batched
range counts, range pairs and kNN through ``QueryService``, then churn the
index live -- delta-buffer inserts, tombstone deletes, and a compaction
whose atomic snapshot swap leaves every answer bit-identical -- watching
the compile-reuse contract (one executable per shape bucket, zero traces
across the swap) hold in the stats.

The whole stream runs under ``obs.capture()`` (DESIGN.md #11): the demo
ends with the metrics-registry snapshot, a Chrome-trace dump (``TRACE_OUT``
env, default ``trace_demo.json`` in the temp dir -- open in
chrome://tracing or https://ui.perfetto.dev) and its per-phase report
table.  ``make trace-demo`` runs this plus the standalone report CLI.
"""
import os
import tempfile

import numpy as np

from repro import obs
from repro.core import SelfJoinConfig
from repro.data import exponential_dataset
from repro.join import QueryService, SimilarityIndex
from repro.obs.report import build_report, format_report

# the dataset the service indexes (Syn16D at CPU-demo scale)
D = exponential_dataset(num_points=8_000, num_dims=16, seed=0)
cfg = SelfJoinConfig(eps=0.05, k=4, tile_size=32)

index = SimilarityIndex(D, cfg, k_candidates=[2, 3, 4, 6])
print(f"indexed |D|={index.num_points} n={index.num_dims} "
      f"(auto-selected k={index.config.k}, build eps={cfg.eps})")

# persist + reload: a restarted server skips REORDER and the grid build
path = index.save(os.path.join(tempfile.gettempdir(), "similarity_index"))
index = SimilarityIndex.load(path)
print(f"reloaded index from {path}")

service = QueryService(index)
rng = np.random.default_rng(1)

# the whole stream records spans + mirrored metrics (DESIGN.md #11); the
# tracer is off outside this window, so the service is uninstrumented at
# rest -- one attribute check per span site
with obs.capture() as cap:
    # batched range queries at mixed batch sizes and radii
    for nq, eps in [(3, 0.05), (100, 0.03), (57, 0.05), (100, 0.02)]:
        q = D[rng.choice(len(D), size=nq, replace=False)]
        res = service.range_count(q, eps)
        print(f"range_count  nq={nq:4d} eps={eps:.3f} -> "
              f"{res.stats.num_results:7d} neighbours  "
              f"bucket={res.stats.bucket:4d} new_traces={res.stats.num_traces} "
              f"dispatches={res.stats.num_device_dispatches}")

    # materialized pairs
    q = D[:64]
    res = service.range_pairs(q, 0.04)
    print(f"range_pairs  nq=64  eps=0.040 -> {res.pairs.shape[0]:7d} pairs")

    # kNN by adaptive eps expansion
    kn = service.knn(q, k=8)
    print(f"knn          nq=64  k=8       -> final eps={kn.stats.eps:.3f} "
          f"after {kn.stats.eps_rounds} expansion round(s); "
          f"nearest of q0: ids={kn.indices[0, :4].tolist()} "
          f"dists={np.round(kn.distances[0, :4], 4).tolist()}")

    # spot-check: the served counts equal float64 brute force on a subset
    sub = D[:1500]
    got = service.range_count(sub, 0.05).counts
    d2 = ((sub[:, None, :].astype(np.float64) - D[None, :, :].astype(np.float64)) ** 2).sum(-1)
    assert np.array_equal(got, (d2 <= 0.05 ** 2).sum(1))
    print("verified against float64 brute force on a 1.5k-query batch.")

    # live churn (DESIGN.md #10): inserts land in a device-resident delta
    # buffer, deletes tombstone, and queries keep serving the LIVE set from
    # the same warm executables -- no rebuild on the request path
    new_pts = exponential_dataset(num_points=300, num_dims=16, seed=2)
    new_ids = index.insert(new_pts)
    index.delete(new_ids[:50])
    index.delete(rng.choice(8_000, size=100, replace=False))
    res = service.range_count(q, 0.04)
    print(f"after churn  nq=64  eps=0.040 -> {res.stats.num_results:7d} neighbours  "
          f"epoch={res.stats.epoch} delta={res.stats.delta_size} "
          f"tombstones={res.stats.tombstone_count} "
          f"new_traces={res.stats.num_traces}")

    # compact: fold the churn into a fresh snapshot behind an atomic swap --
    # same-bucket shapes mean the swap retraces NOTHING warm
    before = service.range_pairs(q, 0.04)
    traces0 = service.total.num_traces
    index.compact()
    after = service.range_pairs(q, 0.04)
    assert np.array_equal(before.pairs, after.pairs)   # bit-identical across swap
    print(f"compacted to epoch {index.epoch}: |live|={index.num_points}, "
          f"answers bit-identical, "
          f"swap cost {service.total.num_traces - traces0} new traces")

t = service.total
print(f"stream totals: {t.num_requests} requests, {t.num_queries} queries, "
      f"{t.num_traces} program traces over {sorted(service.buckets_used)} "
      f"buckets, {t.num_device_dispatches} dispatches")

# -- observability epilogue (DESIGN.md #11) ---------------------------------
# the span counts are exact mirrors of the stats above: one "trace" instant
# per program trace, one "dispatch" span per device launch
assert cap.span_count(cat="trace") == t.num_traces
assert cap.span_count(cat="dispatch") == t.num_device_dispatches
assert cap.metric("service_dispatches_total") == t.num_device_dispatches

print("\nmetrics snapshot (Prometheus exposition format, service series):")
for line in obs.REGISTRY.to_prometheus_text().splitlines():
    if line.startswith(("service_", "index_", "# TYPE service", "# TYPE index")):
        print(f"  {line}")

trace_path = os.environ.get(
    "TRACE_OUT", os.path.join(tempfile.gettempdir(), "trace_demo.json")
)
cap.write_chrome_trace(trace_path)
print(f"\nwrote Chrome trace to {trace_path} "
      f"(open in chrome://tracing or https://ui.perfetto.dev)")
print(format_report(build_report(cap.chrome_trace()["traceEvents"])))
