"""Distributed self-join with entity partitioning + ring pass (paper Sec. 6)
on 8 simulated devices.  Run as its own process (device count must be set
before jax initializes):

    PYTHONPATH=src python examples/distributed_ring_join.py

Three layers are exercised:

  * the grid-indexed ``DistributedSelfJoinEngine`` (DESIGN.md #7): per-shard
    grid index + per-round bipartite tile join, so the ring path keeps the
    paper's candidate filtering (num_candidates << |D|^2);
  * its device-fused form (``fused=True``, DESIGN.md #7a): the same BSP
    schedule as ONE compiled ``shard_map`` program -- padded tile tables
    rotate as ``ppermute`` payloads inside a ``fori_loop``;
  * the ``shard_map``/``ppermute`` wire protocol of ``ring_self_join_counts``
    -- the dense transport reference.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core import DistributedSelfJoinEngine, SelfJoinConfig  # noqa: E402
from repro.core.brute import brute_counts  # noqa: E402
from repro.core.distributed import ring_comm_elements, ring_self_join_counts  # noqa: E402
from repro.data import exponential_dataset  # noqa: E402

D = exponential_dataset(8_000, 16, seed=1)
eps = 0.05

mesh = jax.make_mesh((8,), ("data",))

# grid-indexed distributed engine: the paper's per-worker indexed join
engine = DistributedSelfJoinEngine(
    D, SelfJoinConfig(eps=eps, k=4), mesh=mesh, assignment="dynamic"
)
res = engine.count()
s = res.stats
print(f"|D|={D.shape[0]} on {len(jax.devices())} devices (ring of {s.num_workers})")
print(f"total ordered pairs: {int(res.counts.sum())}")
print(f"candidates evaluated: {s.num_candidates} "
      f"(dense ring would do {s.num_candidates_dense}; "
      f"filter ratio {s.candidate_filter_ratio:.3f})")
print(f"elements communicated: {s.comm_elements} (= (|p|-1)|D|, paper Sec. 6.3)")

# device-fused ring: identical counts from one compiled program
fused_engine = DistributedSelfJoinEngine(
    D, SelfJoinConfig(eps=eps, k=4), mesh=mesh, assignment="dynamic", fused=True
)
fused = fused_engine.count()
assert np.array_equal(res.counts, fused.counts)
print(f"fused ring: {fused_engine.fused_traces} trace, "
      f"{fused.stats.num_device_dispatches} device dispatch "
      f"(host-driven loop: {s.num_device_dispatches} dispatches)")

# wire-protocol reference: dense shard_map ring, same counts
counts_wire = ring_self_join_counts(D, eps, mesh, "data")
assert np.array_equal(res.counts, counts_wire)

sub = D[:1500]
assert np.array_equal(
    ring_self_join_counts(sub, eps, mesh, "data"), brute_counts(sub, eps)
)
assert ring_comm_elements(D.shape[0], 8) == 7 * D.shape[0]
print("indexed engine == shard_map ring == brute force: verified.")
