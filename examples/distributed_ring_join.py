"""Distributed self-join with entity partitioning + ring pass (paper Sec. 6.3)
on 8 simulated devices.  Run as its own process (device count must be set
before jax initializes):

    PYTHONPATH=src python examples/distributed_ring_join.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core.brute import brute_counts  # noqa: E402
from repro.core.distributed import ring_comm_elements, ring_self_join_counts  # noqa: E402
from repro.data import exponential_dataset  # noqa: E402

D = exponential_dataset(8_000, 16, seed=1)
eps = 0.05

mesh = jax.make_mesh((8,), ("data",))
counts = ring_self_join_counts(D, eps, mesh, "data")

print(f"|D|={D.shape[0]} on {len(jax.devices())} devices (ring of 8)")
print(f"total ordered pairs: {int(counts.sum())}")
print(f"elements communicated: {ring_comm_elements(D.shape[0], 8)} "
      f"(= (|p|-1)|D|, paper Sec. 6.3)")

sub = D[:1500]
assert np.array_equal(
    ring_self_join_counts(sub, eps, mesh, "data"), brute_counts(sub, eps)
)
print("verified against brute force on a 1.5k subset.")
