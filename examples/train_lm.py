"""End-to-end driver: train a reduced LM for a few hundred steps on CPU with
checkpoint/restart, using the same launcher as the production path.

    PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import tempfile

from repro.launch.train import main

ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
try:
    # phase 1: 120 steps, checkpoint every 50
    main([
        "--arch", "xlstm_125m", "--steps", "120", "--batch", "8",
        "--seq", "64", "--ckpt-dir", ckpt, "--ckpt-every", "50", "--dedup",
    ])
    # phase 2: simulate a restart -- resumes from step 100's checkpoint
    print("\n--- simulated restart (fault tolerance) ---")
    final_loss = main([
        "--arch", "xlstm_125m", "--steps", "200", "--batch", "8",
        "--seq", "64", "--ckpt-dir", ckpt, "--ckpt-every", "100",
    ])
    print(f"final loss after resume: {final_loss:.4f}")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
