# Convenience targets; `make ci` mirrors .github/workflows/ci.yml.

PYTHON ?= python

.PHONY: install ci test test-8dev bench-engine bench-smoke bench-compare bench-baseline quickstart serve-demo trace-demo

install:
	$(PYTHON) -m pip install -r requirements-dev.txt

ci: install test test-8dev bench-smoke bench-compare

test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q --durations=15 --budget-seconds 1800

# the whole in-process suite against 8 simulated host devices (CI leg 2)
test-8dev:
	PYTHONPATH=src REPRO_TEST_DEVICES=8 $(PYTHON) -m pytest -x -q --durations=15 --budget-seconds 1800

bench-engine:
	PYTHONPATH=src:. $(PYTHON) benchmarks/bench_engine.py

# Tiny-configuration runs of the distributed + serving + hybrid-tier +
# mutable-index benchmarks (ring ppermute wire pass, entity-partition
# balance on the indexed engine, the query-service warm-QPS/compile-reuse
# pass, the dense-vs-indexed crossover sweep, and the churn-stream
# delta-vs-rebuild pass) so no tier can silently rot between PRs.
# bench_comm/bench_partition_balance/bench_dense/bench_service/
# bench_mutation/bench_scaling also drop BENCH_*.json into BENCH_OUT_DIR
# (default .bench_out) for bench-compare (bench_scaling runs in the compare
# step itself); bench_service additionally writes TRACE_service.json, the
# obs span dump CI uploads and feeds through `repro.obs.report`.
bench-smoke:
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_comm.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_partition_balance.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_service.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_dense.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_mutation.py

# Regression gate: rerun the JSON-emitting benchmarks at tiny scale and
# diff against the committed baselines (contracts exact, wall times within
# a slack factor; see benchmarks/compare.py).  Non-zero exit on regression.
bench-compare:
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_dense.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_service.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_comm.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_mutation.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_partition_balance.py
	PYTHONPATH=src:. BENCH_SMOKE=1 $(PYTHON) benchmarks/bench_scaling.py
	PYTHONPATH=src:. $(PYTHON) benchmarks/compare.py

# Regenerate the committed baselines in-place (run on a quiet machine,
# review the diff, commit).
bench-baseline:
	PYTHONPATH=src:. BENCH_SMOKE=1 BENCH_OUT_DIR=benchmarks/baselines $(PYTHON) benchmarks/bench_dense.py
	PYTHONPATH=src:. BENCH_SMOKE=1 BENCH_OUT_DIR=benchmarks/baselines $(PYTHON) benchmarks/bench_service.py
	PYTHONPATH=src:. BENCH_SMOKE=1 BENCH_OUT_DIR=benchmarks/baselines $(PYTHON) benchmarks/bench_comm.py
	PYTHONPATH=src:. BENCH_SMOKE=1 BENCH_OUT_DIR=benchmarks/baselines $(PYTHON) benchmarks/bench_mutation.py
	PYTHONPATH=src:. BENCH_SMOKE=1 BENCH_OUT_DIR=benchmarks/baselines $(PYTHON) benchmarks/bench_partition_balance.py
	PYTHONPATH=src:. BENCH_SMOKE=1 BENCH_OUT_DIR=benchmarks/baselines $(PYTHON) benchmarks/bench_scaling.py

quickstart:
	PYTHONPATH=src $(PYTHON) examples/quickstart.py

# The serving-tier demo: build + persist + reload a SimilarityIndex and
# drive a mixed range/kNN request stream through QueryService.
serve-demo:
	PYTHONPATH=src $(PYTHON) examples/query_service.py

# serve-demo with the observability layer on: prints the metrics snapshot,
# writes a Chrome trace (TRACE_OUT, default trace_demo.json -- open in
# chrome://tracing or Perfetto) and its per-phase report table.
trace-demo:
	PYTHONPATH=src TRACE_OUT=trace_demo.json $(PYTHON) examples/query_service.py
	PYTHONPATH=src $(PYTHON) -m repro.obs.report trace_demo.json
