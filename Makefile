# Convenience targets; `make ci` mirrors .github/workflows/ci.yml, except
# the workflow additionally deselects two pre-existing seed failures
# (see ROADMAP.md open items) -- `make test` runs the full tier-1 command.

PYTHON ?= python

.PHONY: install ci test bench-engine quickstart

install:
	$(PYTHON) -m pip install -r requirements-dev.txt

ci: install test

test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

bench-engine:
	PYTHONPATH=src:. $(PYTHON) benchmarks/bench_engine.py

quickstart:
	PYTHONPATH=src $(PYTHON) examples/quickstart.py
