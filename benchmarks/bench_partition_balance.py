"""Paper Fig. 10: computation time histogram of entity-partitioned batches.

Partitions the query set into N_b batches and times each batch's join
against the full dataset; near-equal batch times (small max/min spread) are
what make round-robin assignment near-ideal (paper Sec. 6.2).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import record
from repro.core import SelfJoinConfig, make_partition
from repro.core.grid import adjacent_cell_pairs, build_grid, build_tile_plan
from repro.core.reorder import variance_reorder
from repro.kernels import ops
from repro.data import paper_dataset

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "partition_times.json")


def batch_times(d, eps, k, n_batches, tile_size=32, dim_block=32):
    work, _ = variance_reorder(d)
    grid = build_grid(work, eps, k)
    plan = build_tile_plan(grid, tile_size, sortidu=True)
    tiles, tlen = ops.make_tiles(
        grid.pts_sorted, plan.tile_start, plan.tile_len, tile_size, dim_block
    )
    part = make_partition(plan.num_pairs, 1, n_batches)
    times = []
    for b in range(part.num_batches):
        lo, hi = part.query_range(b)
        t0 = time.perf_counter()
        ops.tile_counts(
            tiles, tlen, plan.pair_a[lo:hi], plan.pair_b[lo:hi],
            eps=eps, dim_block=dim_block, shortc=True,
        )
        times.append(time.perf_counter() - t0)
    return np.asarray(times)


def run():
    results = {}
    for name, scale, eps, nb in [("Syn16D2M", 0.002, 0.05, 32), ("SuSy", 0.0008, 0.02, 32)]:
        d = paper_dataset(name, scale)
        times = batch_times(d, eps, 6, nb)
        results[name] = times.tolist()
        record(
            f"fig10/{name}/Nb={nb}", float(times.sum() * 1e6),
            f"min={times.min():.3f}s;max={times.max():.3f}s;"
            f"rel_spread={(times.max() - times.min()) / times.mean():.3f}",
        )
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f)


if __name__ == "__main__":
    run()
