"""Paper Fig. 10: computation time histogram of entity-partitioned batches.

Partitions the query set into N_b batches and times each batch's join
against the full dataset; near-equal batch times (small max/min spread) are
what make round-robin assignment near-ideal (paper Sec. 6.2).

Also exercises the grid-indexed distributed tier: the per-batch candidate
cost estimate of ``DistributedSelfJoinEngine`` drives round-robin vs.
``assign_dynamic`` (LPT) worker loads, and the engine's candidate filter
ratio vs. the dense ring is recorded (the repaired-index effect).

``--tiny`` (or BENCH_SMOKE=1) shrinks the datasets so `make bench-smoke`
keeps this path alive at CI scale.  Emits ``BENCH_partition.json`` for the
regression gate: the worker-load balance facts (round-robin and LPT max
loads, LPT never worse than round-robin) are deterministic contracts, the
per-figure wall times are slack-gated metrics.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import emit_bench_json, record
from repro.core import (
    DistributedSelfJoinEngine,
    SelfJoinConfig,
    assign_dynamic,
    make_partition,
)
from repro.core.grid import adjacent_cell_pairs, build_grid, build_tile_plan
from repro.core.reorder import variance_reorder
from repro.kernels import ops
from repro.data import paper_dataset

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "partition_times.json")

FULL_CELLS = [("Syn16D2M", 0.002, 0.05, 32), ("SuSy", 0.0008, 0.02, 32)]
TINY_CELLS = [("Syn16D2M", 0.0005, 0.05, 8), ("SuSy", 0.0002, 0.02, 8)]


def batch_times(d, eps, k, n_batches, tile_size=32, dim_block=32):
    work, _ = variance_reorder(d)
    grid = build_grid(work, eps, k)
    plan = build_tile_plan(grid, tile_size, sortidu=True)
    tiles, tlen = ops.make_tiles(
        grid.pts_sorted, plan.tile_start, plan.tile_len, tile_size, dim_block
    )
    part = make_partition(plan.num_pairs, 1, n_batches)
    times = []
    for b in range(part.num_batches):
        lo, hi = part.query_range(b)
        t0 = time.perf_counter()
        ops.tile_counts(
            tiles, tlen, plan.pair_a[lo:hi], plan.pair_b[lo:hi],
            eps=eps, dim_block=dim_block, shortc=True,
        )
        times.append(time.perf_counter() - t0)
    return np.asarray(times)


def dist_balance(d, eps, k, workers=8, n_batches=32):
    """Round-robin vs. assign_dynamic worker loads on the indexed engine."""
    cfg = SelfJoinConfig(eps=eps, k=k)
    rr = DistributedSelfJoinEngine(
        d, cfg, num_workers=workers, num_batches=n_batches
    )
    res = rr.count()
    # dynamic loads from the same memoized cost estimates -- no need to
    # build a second engine just to re-run the LPT assignment
    costs = rr.estimate_batch_costs()
    dyn_assign = assign_dynamic(costs, workers)
    dyn_loads = np.zeros(workers)
    np.add.at(dyn_loads, dyn_assign, costs)
    return rr.worker_loads(), dyn_loads, res.stats


def run(tiny: bool = False):
    results = {}
    contracts: dict = {}
    metrics: dict = {}
    info: dict = {"tiny": tiny}
    for name, scale, eps, nb in (TINY_CELLS if tiny else FULL_CELLS):
        d = paper_dataset(name, scale)
        times = batch_times(d, eps, 6, nb)
        results[name] = times.tolist()
        record(
            f"fig10/{name}/Nb={nb}", float(times.sum() * 1e6),
            f"min={times.min():.3f}s;max={times.max():.3f}s;"
            f"rel_spread={(times.max() - times.min()) / times.mean():.3f}",
        )
        rr_loads, dyn_loads, stats = dist_balance(d, eps, 6, n_batches=nb)
        record(
            f"fig10/{name}/dist-balance/p=8", float(rr_loads.max()),
            f"rr_max={rr_loads.max():.0f};dyn_max={dyn_loads.max():.0f};"
            f"candidates={stats.num_candidates};"
            f"dense={stats.num_candidates_dense};"
            f"filter_ratio={stats.candidate_filter_ratio:.3f}",
        )
        # worker loads come from the memoized candidate-cost estimates --
        # deterministic for a fixed dataset, so they gate exactly
        contracts[f"nb/{name}"] = nb
        contracts[f"rr_max_load/{name}"] = int(round(float(rr_loads.max())))
        contracts[f"lpt_max_load/{name}"] = int(round(float(dyn_loads.max())))
        contracts[f"lpt_max_le_rr/{name}"] = bool(
            dyn_loads.max() <= rr_loads.max()
        )
        metrics[f"batch_wall_us/{name}"] = float(times.sum() * 1e6)
        info[f"rel_spread/{name}"] = round(
            float((times.max() - times.min()) / times.mean()), 3
        )
        info[f"filter_ratio/{name}"] = round(
            float(stats.candidate_filter_ratio), 3
        )
    emit_bench_json("partition", contracts=contracts, metrics=metrics, info=info)
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny", action="store_true",
        default=os.environ.get("BENCH_SMOKE") == "1",
        help="CI-scale configuration (also via BENCH_SMOKE=1)",
    )
    run(tiny=ap.parse_args().tiny)
