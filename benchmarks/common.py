"""Shared benchmark utilities.

CPU-scale note (DESIGN.md #5): paper dataset sizes (|D| up to 5M) are
shrunk by default so each figure reproduces in minutes on 1 CPU core; the
full algorithm, eps values, lambda=40 distributions and k choices are the
paper's.  ``--scale`` restores larger sizes.  Absolute wall times on CPU are
indicative only -- architecture-level performance claims live in the
roofline analysis (EXPERIMENTS.md #Roofline).
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timeit(fn: Callable, repeats: int = 1) -> float:
    """Best-of wall time in microseconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6
