"""Shared benchmark utilities.

CPU-scale note (DESIGN.md #5): paper dataset sizes (|D| up to 5M) are
shrunk by default so each figure reproduces in minutes on 1 CPU core; the
full algorithm, eps values, lambda=40 distributions and k choices are the
paper's.  ``--scale`` restores larger sizes.  Absolute wall times on CPU are
indicative only -- architecture-level performance claims live in the
roofline analysis (EXPERIMENTS.md #Roofline).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from typing import Callable, Dict, List, Sequence, Tuple

ROWS: List[Tuple[str, float, str]] = []

# Machine-readable benchmark outputs (consumed by benchmarks/compare.py).
# Written under BENCH_OUT_DIR -- NOT the repo root -- so a CI smoke run can
# never clobber the committed baselines in benchmarks/baselines/.
BENCH_OUT_DIR = os.environ.get("BENCH_OUT_DIR", ".bench_out")


def emit_bench_json(
    name: str,
    *,
    contracts: Dict[str, object],
    metrics: Dict[str, float],
    info: Dict[str, object] | None = None,
) -> str:
    """Write ``BENCH_<name>.json`` for the regression gate; return its path.

    Three sections with three comparison rules (see ``compare.py``):
    ``contracts`` are deterministic facts (dispatch decisions, trace
    counts, parity verdicts) diffed EXACTLY; ``metrics`` are wall-time
    measurements diffed within a slack factor; ``info`` is context
    (dataset sizes, measured crossover points) recorded but never gated.
    """
    out_dir = BENCH_OUT_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "bench": name,
        "contracts": contracts,
        "metrics": {k: round(float(v), 1) for k, v in metrics.items()},
        "info": info or {},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", flush=True)
    return path

def write_trace(cap, name: str) -> str:
    """Write a capture's Chrome trace as ``TRACE_<name>.json``; return path.

    Lands next to the BENCH_*.json rows in ``BENCH_OUT_DIR`` so CI can
    upload the trace as an artifact and run ``repro.obs.report`` over it
    (a malformed trace fails the build).
    """
    os.makedirs(BENCH_OUT_DIR, exist_ok=True)
    path = os.path.join(BENCH_OUT_DIR, f"TRACE_{name}.json")
    cap.write_chrome_trace(path)
    print(f"wrote {path}", flush=True)
    return path


def span_summary(cap) -> Dict[str, object]:
    """Compact per-category span summary of a capture, for BENCH info rows.

    ``{cat: {"count": N, "total_us": T}}`` -- enough to see where a bench
    run spent its time without shipping the whole event list.
    """
    from repro.obs.report import build_report

    rep = build_report(cap.chrome_trace()["traceEvents"])
    out: Dict[str, object] = {}
    for cat, names in rep["phases"].items():
        count = sum(a["count"] for a in names.values())
        total = sum(a["total_us"] for a in names.values())
        out[cat] = {"count": count, "total_us": round(total, 1)}
    return out


# Shared fused-vs-host measurement for the distributed engine (used by
# bench_comm's contract row and bench_scaling's per-|p| rows).  Runs in a
# subprocess: the 8-device flag must precede jax init.
_FUSED_VS_HOST_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, time
    sys.path.insert(0, sys.argv[1])
    import numpy as np
    import jax
    from repro.core import DistributedSelfJoinEngine, SelfJoinConfig
    from repro.data import exponential_dataset

    n, dims = int(sys.argv[2]), int(sys.argv[3])
    ps = [int(x) for x in sys.argv[4].split(",")]
    D = exponential_dataset(n, dims, seed=5)
    cfg = SelfJoinConfig(eps=0.06, k=4, tile_size=16)
    for p in ps:
        mesh = jax.make_mesh((p,), ("data",))
        host_eng = DistributedSelfJoinEngine(D, cfg, mesh=mesh)
        host_res = host_eng.count()          # warm the chunk programs
        t0 = time.perf_counter()
        host_res = host_eng.count()
        host_us = (time.perf_counter() - t0) * 1e6
        fused_eng = DistributedSelfJoinEngine(D, cfg, mesh=mesh, fused=True)
        fused_res = fused_eng.count()        # pack + trace + compile + run
        assert np.array_equal(fused_res.counts, host_res.counts), p
        t0 = time.perf_counter()
        fused_res = fused_eng.count()        # warm: one dispatch, one program
        fused_us = (time.perf_counter() - t0) * 1e6
        assert fused_eng.fused_traces == 1, "fused ring retraced"
        assert fused_res.stats.num_device_dispatches == 1

        # pairs mode (DESIGN.md #7b): the same two drivers materializing the
        # full pair list; the fused ring packs it in ONE device dispatch
        host_pr = host_eng.self_join_pairs()   # warm the chunk programs
        t0 = time.perf_counter()
        host_pr = host_eng.self_join_pairs()
        host_pairs_us = (time.perf_counter() - t0) * 1e6
        fused_pr = fused_eng.self_join_pairs() # pack reuse + trace + run
        assert (set(map(tuple, fused_pr.pairs.tolist()))
                == set(map(tuple, host_pr.pairs.tolist()))), p
        t0 = time.perf_counter()
        fused_pr = fused_eng.self_join_pairs() # warm: converged (cap, hit_cap)
        fused_pairs_us = (time.perf_counter() - t0) * 1e6
        assert fused_eng.fused_pairs_traces == 1, "fused pairs retraced"
        assert fused_pr.stats.num_device_dispatches == 1
        assert fused_pr.stats.overflow_retries == 0

        print("ROW", p, fused_us, host_us,
              host_res.stats.num_device_dispatches,
              host_res.stats.num_candidates, flush=True)
        print("PROW", p, fused_pairs_us, host_pairs_us,
              fused_pr.stats.overflow_retries, len(fused_pr.pairs), flush=True)
    """
)


def measure_fused_vs_host(
    n: int, dims: int, workers: Sequence[int], timeout: int = 1800
) -> Tuple[
    List[Tuple[int, float, float, int, int]],
    List[Tuple[int, float, float, int, int]],
]:
    """Warm fused vs host-driven join times on |p|-device meshes.

    Returns ``(count_rows, pairs_rows)``:

    - ``count_rows``: ``[(p, fused_us, host_us, host_dispatches,
      candidates)]`` where ``candidates`` is the point-comparison volume the
      grid index actually evaluated (filter ratio = candidates / n^2,
      deterministic for a fixed dataset);
    - ``pairs_rows``: ``[(p, fused_pairs_us, host_pairs_us,
      overflow_retries, num_pairs)]`` for the pair-materializing mode.

    The subprocess asserts count AND pair-set parity plus the fused
    one-trace / one-dispatch / zero-retry contracts.
    """
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [
            sys.executable, "-c", _FUSED_VS_HOST_SCRIPT, src,
            str(n), str(dims), ",".join(str(p) for p in workers),
        ],
        capture_output=True, text=True, timeout=timeout,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"fused-vs-host subprocess failed:\n{out.stderr[-2000:]}"
        )
    rows, prows = [], []
    for line in out.stdout.splitlines():
        if line.startswith("ROW "):
            _, p, fused_us, host_us, host_disp, cand = line.split()
            rows.append(
                (int(p), float(fused_us), float(host_us), int(host_disp),
                 int(cand))
            )
        elif line.startswith("PROW "):
            _, p, fp_us, hp_us, retries, npairs = line.split()
            prows.append(
                (int(p), float(fp_us), float(hp_us), int(retries),
                 int(npairs))
            )
    return rows, prows


def record(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timeit(fn: Callable, repeats: int = 1) -> float:
    """Best-of wall time in microseconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6
