"""Paper Figs. 5/8/9: response time vs eps, GPU-Join vs the EGO baseline.

Covers the three dataset regimes of the paper: small real-world stand-ins
(Fig. 5), larger real-world (Fig. 8: SuSy/Songs profiles), and worst-case
exponential synthetics (Fig. 9).  Selectivity S_D is reported per point, as
the paper does for reproducibility.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import record, timeit
from repro.core import SelfJoinConfig, select_k, self_join
from repro.core.ego import ego_join_counts
from repro.data import paper_dataset

# (figure, dataset, |D| scale, eps list, run EGO baseline)
CASES = [
    ("fig5", "CoocTexture", 0.06, [0.05, 0.1, 0.2], True),
    ("fig5", "ColorHist", 0.06, [0.05, 0.2, 0.5], True),
    ("fig5", "LayoutHist", 0.06, [0.05, 0.2, 0.5], True),
    ("fig8", "SuSy", 0.0012, [0.01, 0.02], True),
    ("fig8", "Songs", 0.008, [0.005, 0.01], True),
    ("fig9", "Syn16D2M", 0.002, [0.03, 0.05], True),
    ("fig9", "Syn32D2M", 0.002, [0.08, 0.1], True),
    ("fig9", "Syn64D2M", 0.002, [0.16, 0.18], True),
]


def run(scale_mult: float = 1.0):
    for fig, name, scale, eps_list, with_ego in CASES:
        d = paper_dataset(name, scale * scale_mult)
        for eps in eps_list:
            # k via the paper's Sec. 5.6 memory-op model (at reduced |D| the
            # optimum shifts below the paper's k=6 -- fewer points per cell).
            # SHORTC off in the CPU timing path: the vectorized masking costs
            # 2x matmuls with no skip benefit on 1 core (the skip is real on
            # the TPU kernel; see tests + kernel roofline).
            k = select_k(d, eps, ks=[2, 3, 4, 6])
            cfg = SelfJoinConfig(eps=eps, k=k, reorder=True, sortidu=True,
                                 shortc=False, tile_size=32,
                                 dim_block=16)
            r = self_join(d, cfg)            # warmup: XLA compiles here
            t = timeit(lambda: self_join(d, cfg))  # steady-state response
            sd = r.stats.selectivity
            record(f"{fig}/{name}/eps={eps}/gpujoin", t,
                   f"S_D={sd:.1f};|D|={d.shape[0]};n={d.shape[1]}")
            if with_ego:
                t_ego = timeit(lambda: ego_join_counts(d, eps))
                record(f"{fig}/{name}/eps={eps}/ego", t_ego,
                       f"speedup={t_ego / max(t, 1):.2f}x")


if __name__ == "__main__":
    run()
