"""Mutable-index churn throughput (DESIGN.md #10): delta serving vs rebuild.

Drives a warm ``QueryService`` through a mixed stream of range queries
interleaved with inserts and deletes, two ways:

  * ``mutate``  -- the mutable path: inserts land in the device-resident
    delta buffer, deletes become tombstones, queries keep serving from the
    warm executables (the delta/tombstone epilogue is one extra jitted
    dense pass);
  * ``rebuild`` -- the pre-#10 alternative: every mutation rebuilds the
    whole index from scratch and re-warms the service.

Rows record the per-operation wall time of both and the speedup; the
stream then compacts and verifies the churned answers are bit-identical to
a fresh index over the same live set (count parity) with ZERO new traces
from the swap (the shape-bucket contract).  ``BENCH_mutation.json`` pins
those two facts as contracts and gates both wall times.

``--tiny`` (or BENCH_SMOKE=1) shrinks the dataset so `make bench-smoke`
keeps the churn path alive at CI scale.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import emit_bench_json, record
from repro.core import SelfJoinConfig
from repro.data import exponential_dataset
from repro.join import QueryService, SimilarityIndex

# n sits comfortably below its pow2 point bucket (1900 -> 2048, 20000 ->
# 32768) so the whole churn stream -- and the compacted snapshot -- stays
# inside the warm shape buckets and the swap_traces == 0 contract holds
FULL = dict(n=20_000, dims=16, eps=0.04, nq=256, ops=30, batch=64)
TINY = dict(n=1_900, dims=16, eps=0.06, nq=64, ops=10, batch=32)


def _stream(p):
    """The mutation schedule: (kind, payload) per op, query after each.

    Inserts are drawn from the SAME distribution as the dataset, as real
    churn would be -- off-distribution inserts would legitimately grow the
    grid's tile bucket and retrace at the swap.
    """
    pool = exponential_dataset(
        p["batch"] * ((p["ops"] + 1) // 2), p["dims"], seed=6
    )
    ops = []
    for i in range(p["ops"]):
        if i % 2 == 0:
            j = i // 2
            ops.append(("insert", pool[j * p["batch"] : (j + 1) * p["batch"]]))
        else:
            ops.append(("delete", p["batch"] // 2))
    return ops


def run(tiny: bool = False):
    p = TINY if tiny else FULL
    d = exponential_dataset(p["n"], p["dims"], seed=5)
    cfg = SelfJoinConfig(eps=p["eps"], k=4, tile_size=32)
    rng = np.random.default_rng(11)
    q = d[rng.choice(p["n"], size=p["nq"], replace=False)]
    ops = _stream(p)

    # -- mutable path: delta inserts + tombstones on one warm service ------
    idx = SimilarityIndex(d, cfg)
    svc = QueryService(idx)
    svc.range_count(q, p["eps"])                 # warm the clean-stream path
    live = np.arange(p["n"])
    ins0 = idx.insert(ops[0][1])                 # warm the churn epilogue
    idx.delete(ins0[: p["batch"] // 2])
    live_extra = list(ins0[p["batch"] // 2 :])
    svc.range_count(q, p["eps"])
    t0 = time.perf_counter()
    for kind, payload in ops[1:]:
        if kind == "insert":
            live_extra.extend(idx.insert(payload))
        else:
            kill = rng.choice(live, size=payload, replace=False)
            idx.delete(kill)
            live = np.setdiff1d(live, kill, assume_unique=True)
        svc.range_count(q, p["eps"])
    mutate_us = (time.perf_counter() - t0) / (len(ops) - 1) * 1e6

    # -- compact: atomic swap must cost zero traces, answers identical -----
    churned = svc.range_count(q, p["eps"])
    traces0 = svc.total.num_traces
    idx.compact()
    compacted = svc.range_count(q, p["eps"])
    swap_traces = svc.total.num_traces - traces0
    count_parity = bool(np.array_equal(churned.counts, compacted.counts))
    assert count_parity, "compact changed answers"

    # fresh index over the same live set: the churned answers were right
    fresh = QueryService(SimilarityIndex(idx.points, cfg))
    assert np.array_equal(fresh.range_count(q, p["eps"]).counts, churned.counts)

    # -- rebuild-per-change alternative (measured on fewer ops: it is the
    # slow path by construction; per-op cost is what matters) --------------
    n_rebuild = max(2, (len(ops) - 1) // 5)
    pts = d.copy()
    t0 = time.perf_counter()
    for kind, payload in ops[1 : 1 + n_rebuild]:
        if kind == "insert":
            pts = np.concatenate([pts, payload])
        else:
            pts = pts[payload:]
        rb = QueryService(SimilarityIndex(pts, cfg))
        rb.range_count(q, p["eps"])
    rebuild_us = (time.perf_counter() - t0) / n_rebuild * 1e6
    speedup = rebuild_us / mutate_us

    record(
        "mutation/mutate_per_op", mutate_us,
        f"delta={idx.epoch};qps={p['nq'] / (mutate_us / 1e6):.0f};"
        f"swap_traces={swap_traces}",
    )
    record(
        "mutation/rebuild_per_op", rebuild_us,
        f"speedup_mutate_vs_rebuild={speedup:.1f}",
    )
    emit_bench_json(
        "mutation",
        contracts={
            # the shape-bucket contract: swapping the compacted snapshot in
            # costs no new executables, and the churned stream served the
            # same counts a from-scratch index over the live set serves
            "swap_traces": swap_traces,
            "count_parity": count_parity,
            "epoch_after_compact": idx.epoch,
        },
        metrics={
            "mutate_per_op_us": mutate_us,
            "rebuild_per_op_us": rebuild_us,
        },
        info={
            "n": p["n"], "dims": p["dims"], "eps": p["eps"],
            "nq": p["nq"], "ops": p["ops"], "batch": p["batch"],
            "speedup_mutate_vs_rebuild": round(speedup, 1), "tiny": tiny,
        },
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny", action="store_true",
        default=os.environ.get("BENCH_SMOKE") == "1",
        help="CI-scale configuration (also via BENCH_SMOKE=1)",
    )
    run(tiny=ap.parse_args().tiny)
