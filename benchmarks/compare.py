"""Benchmark regression gate: diff fresh BENCH_*.json against baselines.

``make bench-compare`` runs the tiny-mode benchmarks into ``BENCH_OUT_DIR``
(default ``.bench_out``) and then this script against the committed
baselines in ``benchmarks/baselines/``.  Three rules, one per section of
``common.emit_bench_json``:

- **contracts** diff EXACTLY.  These are deterministic facts -- the cost
  model's dispatch decisions along the dims sweep, trace counts and bucket
  sets of a fixed request stream, tier-parity verdicts.  Any drift means
  behaviour changed, not the machine.
- **metrics** (wall-time microseconds) gate within a slack factor
  (default 8x, ``BENCH_COMPARE_FACTOR``): CI boxes are noisy and share
  cores, so only order-of-magnitude regressions fail; a metric present in
  the baseline but missing from the fresh run also fails (a benchmark
  silently dropping rows is itself a regression).
- **info** is recorded context and never gated.

Exit status is non-zero iff any baseline fails, so CI can gate on it.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import List

DEFAULT_FACTOR = 8.0


def compare_payloads(name: str, base: dict, cur: dict, factor: float) -> List[str]:
    """Return a list of human-readable failures (empty == pass)."""
    failures: List[str] = []
    b_con, c_con = base.get("contracts", {}), cur.get("contracts", {})
    for key, want in sorted(b_con.items()):
        if key not in c_con:
            failures.append(f"{name}: contract {key!r} missing from current run")
        elif c_con[key] != want:
            failures.append(
                f"{name}: contract {key!r} changed: "
                f"baseline {want!r} -> current {c_con[key]!r}"
            )
    b_met, c_met = base.get("metrics", {}), cur.get("metrics", {})
    for key, want in sorted(b_met.items()):
        if key not in c_met:
            failures.append(f"{name}: metric {key!r} missing from current run")
            continue
        got = float(c_met[key])
        # only slower-than-slack fails; faster is never a regression
        if got > float(want) * factor:
            failures.append(
                f"{name}: metric {key!r} regressed: "
                f"{want:.1f}us -> {got:.1f}us (> {factor:g}x slack)"
            )
    return failures


def compare_dirs(baseline_dir: str, current_dir: str, factor: float) -> List[str]:
    failures: List[str] = []
    baselines = sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json")))
    if not baselines:
        return [f"no BENCH_*.json baselines found in {baseline_dir}"]
    for bpath in baselines:
        fname = os.path.basename(bpath)
        cpath = os.path.join(current_dir, fname)
        with open(bpath) as f:
            base = json.load(f)
        if not os.path.exists(cpath):
            failures.append(f"{fname}: no fresh result in {current_dir}")
            continue
        with open(cpath) as f:
            cur = json.load(f)
        failures.extend(compare_payloads(fname, base, cur, factor))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--baseline", default=os.path.join(os.path.dirname(__file__), "baselines"),
        help="directory of committed BENCH_*.json baselines",
    )
    ap.add_argument(
        "--current", default=os.environ.get("BENCH_OUT_DIR", ".bench_out"),
        help="directory of freshly produced BENCH_*.json results",
    )
    ap.add_argument(
        "--factor", type=float,
        default=float(os.environ.get("BENCH_COMPARE_FACTOR", DEFAULT_FACTOR)),
        help="metric slack factor (contracts are always exact)",
    )
    args = ap.parse_args(argv)
    failures = compare_dirs(args.baseline, args.current, args.factor)
    if failures:
        print(f"bench-compare: {len(failures)} regression(s)", file=sys.stderr)
        for f in failures:
            print(f"  FAIL {f}", file=sys.stderr)
        return 1
    print(f"bench-compare: ok (baselines={args.baseline}, "
          f"current={args.current}, factor={args.factor:g})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
