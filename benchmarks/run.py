"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  See benchmarks/common.py for
the CPU-scale note; roofline/architecture numbers live in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "bench_memops",             # Fig. 7  (fast, analytic)
    "bench_engine",             # engine vs host-loop wall time
    "bench_k_sweep",            # Fig. 6
    "bench_eps_sweep",          # Figs. 5/8/9
    "bench_overhead",           # Table 2
    "bench_partition_balance",  # Fig. 10
    "bench_scaling",            # Fig. 11
    "bench_comm",               # Fig. 12
    "bench_dense",              # hybrid tiers: dense-vs-indexed crossover
    "bench_service",            # serving tier: warm QPS vs batch size
    "bench_speedup_summary",    # Table 3
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run()
        except Exception:
            failed.append(mod_name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
