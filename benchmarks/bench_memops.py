"""Paper Fig. 7 / Sec. 5.6: the memory-operation model used to select k,
plus the memory-layout ops the engine moved off the host: tile gathering
(per-tile Python loop vs vectorized gather vs in-jit device gather).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import record, timeit
from repro.core.grid import build_grid, build_tile_plan
from repro.core.tuning import estimate_k_costs, select_k
from repro.data import exponential_dataset, paper_dataset
from repro.kernels import ops


def _make_tiles_loop(pts_sorted, tile_start, tile_len, tile_size, dim_block):
    """The pre-engine per-tile host loop, kept here as the baseline."""
    num_tiles = tile_start.shape[0]
    n = pts_sorted.shape[1]
    n_pad = ((n + dim_block - 1) // dim_block) * dim_block
    tiles = np.zeros((max(num_tiles, 1), tile_size, n_pad), dtype=np.float32)
    for i in range(num_tiles):
        s, l = int(tile_start[i]), int(tile_len[i])
        tiles[i, :l, :n] = pts_sorted[s : s + l]
    return tiles, tile_len.astype(np.int32)


def run():
    d = paper_dataset("Syn16D2M", 0.004)
    ests = estimate_k_costs(d, eps=0.05, ks=[1, 2, 4, 6, 8, 10, 12])
    for e in ests:
        record(
            f"fig7/Syn16D2M/k={e.k}", 0.0,
            f"search_ops={e.search_ops:.3e};compare_ops={e.compare_ops:.3e};"
            f"total={e.total_ops:.3e};cells={e.num_cells}",
        )
    k = select_k(d, 0.05, ks=[1, 2, 4, 6, 8, 10, 12])
    record("fig7/Syn16D2M/selected_k", 0.0, f"k={k}")

    # tiling memops: host loop vs vectorized gather vs device gather ------
    import jax.numpy as jnp

    dd = exponential_dataset(20_000, 16, seed=0)
    grid = build_grid(dd, 0.05, 4)
    plan = build_tile_plan(grid, 32, sortidu=True)
    args = (grid.pts_sorted, plan.tile_start, plan.tile_len, 32, 8)
    loop_us = timeit(lambda: _make_tiles_loop(*args), repeats=3)
    vec_us = timeit(lambda: ops.make_tiles(*args), repeats=3)
    pts_j = jnp.asarray(grid.pts_sorted)
    ts_j = jnp.asarray(plan.tile_start, jnp.int32)
    tl_j = jnp.asarray(plan.tile_len, jnp.int32)

    def dev():
        ops.make_tiles_device(
            pts_j, ts_j, tl_j, tile_size=32, dim_block=8
        ).block_until_ready()

    dev()  # compile outside timing
    dev_us = timeit(dev, repeats=3)
    loop_tiles, _ = _make_tiles_loop(*args)
    vec_tiles, _ = ops.make_tiles(*args)
    assert np.array_equal(loop_tiles, vec_tiles), "tiling layouts diverged"
    record("memops/make_tiles/host_loop", loop_us, f"tiles={plan.num_tiles}")
    record("memops/make_tiles/vectorized", vec_us,
           f"speedup={loop_us / max(vec_us, 1e-9):.2f}x")
    record("memops/make_tiles/device_jit", dev_us,
           f"speedup={loop_us / max(dev_us, 1e-9):.2f}x")


if __name__ == "__main__":
    run()
