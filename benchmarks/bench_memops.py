"""Paper Fig. 7 / Sec. 5.6: the memory-operation model used to select k.

search ops  = |D| * 3^k * log2(|G|);  compare ops = mu / f  (sampled).
"""
from __future__ import annotations

from benchmarks.common import record
from repro.core.tuning import estimate_k_costs, select_k
from repro.data import paper_dataset


def run():
    d = paper_dataset("Syn16D2M", 0.004)
    ests = estimate_k_costs(d, eps=0.05, ks=[1, 2, 4, 6, 8, 10, 12])
    for e in ests:
        record(
            f"fig7/Syn16D2M/k={e.k}", 0.0,
            f"search_ops={e.search_ops:.3e};compare_ops={e.compare_ops:.3e};"
            f"total={e.total_ops:.3e};cells={e.num_cells}",
        )
    k = select_k(d, 0.05, ks=[1, 2, 4, 6, 8, 10, 12])
    record("fig7/Syn16D2M/selected_k", 0.0, f"k={k}")


if __name__ == "__main__":
    run()
