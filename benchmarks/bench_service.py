"""Serving-tier throughput: warm QPS vs batch size, range vs kNN.

The serving analogue of the engine's reuse benchmark: after the per-bucket
executables are warm, a ``QueryService`` request costs host planning + one
(or a few) dispatches of an already-compiled program, so throughput should
scale with batch size.  Rows record queries/second at each batch size for
``range_count`` and ``knn``, plus the compile-reuse contract of the stream
(traces == number of shape buckets touched while warming).

``--tiny`` (or BENCH_SMOKE=1) shrinks the dataset and batch grid so
`make bench-smoke` keeps the serving path alive at CI scale.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import emit_bench_json, record, span_summary, write_trace
from repro import obs
from repro.core import SelfJoinConfig
from repro.data import exponential_dataset
from repro.join import QueryService, SimilarityIndex

FULL = dict(n=40_000, dims=16, eps=0.04, batches=[1, 16, 128, 1024], reps=5, k=8)
TINY = dict(n=2_000, dims=16, eps=0.06, batches=[4, 32, 128], reps=2, k=4)


def run(tiny: bool = False):
    p = TINY if tiny else FULL
    d = exponential_dataset(p["n"], p["dims"], seed=5)
    cfg = SelfJoinConfig(eps=p["eps"], k=4, tile_size=32)
    service = QueryService(SimilarityIndex(d, cfg))
    rng = np.random.default_rng(7)
    contracts: dict = {}
    metrics: dict = {}

    for nq in p["batches"]:
        q = d[rng.choice(p["n"], size=nq, replace=False)]
        service.range_count(q, p["eps"])          # warm the bucket
        t0 = time.perf_counter()
        for _ in range(p["reps"]):
            res = service.range_count(q, p["eps"])
        dt = (time.perf_counter() - t0) / p["reps"]
        assert res.stats.num_traces == 0, "warm request retraced"
        metrics[f"range_count_us/nq={nq}"] = dt * 1e6
        contracts[f"bucket/nq={nq}"] = res.stats.bucket
        contracts[f"tier/nq={nq}"] = res.stats.execution
        record(
            f"service/range_count/nq={nq}", dt * 1e6,
            f"qps={nq / dt:.0f};bucket={res.stats.bucket};"
            f"dispatches={res.stats.num_device_dispatches}",
        )

    for nq in p["batches"]:
        q = d[rng.choice(p["n"], size=nq, replace=False)]
        service.knn(q, p["k"])                    # warm (incl. expansion radii)
        t0 = time.perf_counter()
        for _ in range(p["reps"]):
            res = service.knn(q, p["k"])
        dt = (time.perf_counter() - t0) / p["reps"]
        assert res.stats.num_traces == 0, "warm kNN retraced"
        metrics[f"knn{p['k']}_us/nq={nq}"] = dt * 1e6
        record(
            f"service/knn{p['k']}/nq={nq}", dt * 1e6,
            f"qps={nq / dt:.0f};eps_rounds={res.stats.eps_rounds};"
            f"final_eps={res.stats.eps:.3f}",
        )

    t = service.total
    record(
        "service/stream-contract", float(t.num_traces),
        f"traces={t.num_traces};buckets={sorted(service.buckets_used)};"
        f"requests={t.num_requests};dispatches={t.num_device_dispatches}",
    )
    # the compile-reuse contract is exact: warming this fixed stream must
    # always cost the same trace count over the same bucket set
    contracts["num_traces"] = t.num_traces
    contracts["buckets"] = sorted(service.buckets_used)

    # -- observability contracts (DESIGN.md #11) ---------------------------
    # the timed sections above ran with the tracer DISABLED; zero recorded
    # events is what makes the baselined QPS metrics the disabled-path
    # overhead guard (any always-on instrumentation would also show up as
    # a slack-gated wall-time regression against the pre-obs baselines)
    contracts["obs_disabled_events"] = obs.event_count()
    tr0, dd0 = t.num_traces, t.num_device_dispatches
    with obs.capture() as cap:
        for nq in p["batches"]:
            q = d[rng.choice(p["n"], size=nq, replace=False)]
            service.range_count(q, p["eps"])
        service.knn(q[: p["batches"][0]], p["k"])
    d_tr = service.total.num_traces - tr0
    d_dd = service.total.num_device_dispatches - dd0
    contracts["obs_trace_spans_match"] = cap.span_count(cat="trace") == d_tr
    contracts["obs_dispatch_spans_match"] = (
        cap.span_count(cat="dispatch") == d_dd
        and cap.metric("service_dispatches_total") == d_dd
    )
    write_trace(cap, "service")

    emit_bench_json(
        "service",
        contracts=contracts,
        metrics=metrics,
        info={"n": p["n"], "dims": p["dims"], "eps": p["eps"],
              "requests": service.total.num_requests, "tiny": tiny,
              "obs_spans": span_summary(cap)},
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny", action="store_true",
        default=os.environ.get("BENCH_SMOKE") == "1",
        help="CI-scale configuration (also via BENCH_SMOKE=1)",
    )
    run(tiny=ap.parse_args().tiny)
