"""Paper Table 2: kernel compute time vs host-side overhead fraction.

The paper shows host-side operations (transfers, table construction) are
0.69-1.8% of the response time in high dimensions.  Here: evaluation
(kernel) time vs index construction + planning + scatter (host side).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import record
from repro.core import SelfJoinConfig
from repro.core.grid import build_grid, build_tile_plan
from repro.core.reorder import variance_reorder
from repro.kernels import ops
from repro.data import paper_dataset


def run():
    for name, scale, eps in [("Syn16D2M", 0.004, 0.05), ("SuSy", 0.0012, 0.02)]:
        d = paper_dataset(name, scale)
        cfg = SelfJoinConfig(eps=eps, k=6, tile_size=32)

        t0 = time.perf_counter()
        work, _ = variance_reorder(d, cfg.sample_frac)
        grid = build_grid(work, eps, cfg.k)
        plan = build_tile_plan(grid, cfg.tile_size, sortidu=True)
        tiles, tlen = ops.make_tiles(
            grid.pts_sorted, plan.tile_start, plan.tile_len,
            cfg.tile_size, cfg.dim_block,
        )
        t_host = time.perf_counter() - t0

        t0 = time.perf_counter()
        counts, _ = ops.tile_counts(
            tiles, tlen, plan.pair_a, plan.pair_b,
            eps=eps, dim_block=cfg.dim_block, shortc=True,
        )
        t_kernel = time.perf_counter() - t0

        t0 = time.perf_counter()
        out = np.zeros(d.shape[0], np.int64)
        lane = np.arange(cfg.tile_size, dtype=np.int64)
        idx = plan.tile_start[plan.pair_a].astype(np.int64)[:, None] + lane
        valid = lane[None, :] < plan.tile_len[plan.pair_a][:, None]
        np.add.at(out, np.where(valid, idx, 0),
                  np.where(valid, counts.astype(np.int64), 0))
        t_table = time.perf_counter() - t0

        total = t_host + t_kernel + t_table
        overhead = 100.0 * (t_host + t_table) / total
        record(
            f"table2/{name}", total * 1e6,
            f"compute_s={t_kernel:.3f};total_s={total:.3f};overhead={overhead:.1f}%",
        )


if __name__ == "__main__":
    run()
