"""Dense vs indexed tier across dimensionality (DESIGN.md #9).

The hybrid-execution figure: sweep dims over the paper's exponential
workload (lambda=40, eps=0.06) and measure the warm self-join wall time of
each tier plus the cost model's ``auto`` pick at every point.  As dims grow
the grid's first-k filtering power decays (candidate ratio -> 1) while the
dense tier's full tile cross product grows only linearly in padded width --
so the sweep crosses over, and the model must track it.

Every point asserts tier parity (identical counts) before timing, so the
figure cannot be produced by a wrong kernel.  Emits ``BENCH_dense.json``
(see ``common.emit_bench_json``): the cost model's per-dims decisions and
the parity verdict are exact contracts; wall times are slack-gated
metrics; the measured wall-time crossover is recorded as info.

``--tiny`` (or BENCH_SMOKE=1) shrinks |D| and the dims grid for
``make bench-smoke`` / ``make bench-compare`` at CI scale.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from benchmarks.common import emit_bench_json, record, timeit
from repro.core import SelfJoinConfig, SelfJoinEngine
from repro.data import exponential_dataset

FULL = dict(n=4_000, dims_sweep=[2, 3, 4, 6, 8, 12, 16, 24, 32], reps=3)
TINY = dict(n=1_200, dims_sweep=[2, 4, 6, 8, 16], reps=2)

EPS = 0.06  # the paper's expo-4D working point, held across the sweep


def _cfg(dims: int, mode: str) -> SelfJoinConfig:
    return SelfJoinConfig(
        eps=EPS, k=min(6, dims), tile_size=16, dim_block=8, execution=mode
    )


def run(tiny: bool = False):
    p = TINY if tiny else FULL
    contracts: dict = {}
    metrics: dict = {}
    auto_crossover = None   # first dims where the model picks dense
    wall_crossover = None   # first dims where dense actually measured faster

    for dims in p["dims_sweep"]:
        d = exponential_dataset(p["n"], dims, seed=9)
        eng = {m: SelfJoinEngine(d, _cfg(dims, m)) for m in ("indexed", "dense")}
        res = {m: e.count() for m, e in eng.items()}      # warm + correctness
        assert np.array_equal(
            res["indexed"].counts, res["dense"].counts
        ), f"tier parity broke at dims={dims}"
        us = {m: timeit(e.count, p["reps"]) for m, e in eng.items()}

        dec = SelfJoinEngine(d, _cfg(dims, "auto")).resolve_execution()
        contracts[f"auto_tier/dims={dims}"] = dec.execution
        if auto_crossover is None and dec.execution == "dense":
            auto_crossover = dims
        if wall_crossover is None and us["dense"] < us["indexed"]:
            wall_crossover = dims

        for m in ("indexed", "dense"):
            metrics[f"{m}_us/dims={dims}"] = us[m]
            record(
                f"dense/{m}/dims={dims}", us[m],
                f"picked={dec.execution};"
                f"cost_indexed={dec.cost_indexed:.3g};"
                f"cost_dense={dec.cost_dense:.3g}",
            )

    contracts["parity"] = "ok"   # every sweep point count-matched above
    record(
        "dense/crossover", float(auto_crossover or 0),
        f"auto_crossover_dims={auto_crossover};"
        f"wall_crossover_dims={wall_crossover}",
    )
    emit_bench_json(
        "dense",
        contracts=contracts,
        metrics=metrics,
        info={
            "n": p["n"], "eps": EPS, "dims_sweep": p["dims_sweep"],
            "auto_crossover_dims": auto_crossover,
            "wall_crossover_dims": wall_crossover,
            "tiny": tiny,
        },
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny", action="store_true",
        default=os.environ.get("BENCH_SMOKE") == "1",
        help="CI-scale configuration (also via BENCH_SMOKE=1)",
    )
    run(tiny=ap.parse_args().tiny)
