"""Paper Fig. 6: response time vs indexed dimensions k, REORDER on/off.

Uses the SuSy profile (moderate variance spread) and the Songs profile
(first ~12 dims low-variance -- the case where REORDER matters most).
"""
from __future__ import annotations

from benchmarks.common import record, timeit
from repro.core import SelfJoinConfig, self_join
from repro.data import paper_dataset

KS = [1, 2, 3, 4, 6, 8]


def run():
    for name, scale, eps in [("SuSy", 0.0012, 0.02), ("Songs", 0.008, 0.01)]:
        d = paper_dataset(name, scale)
        for k in KS:
            for reorder in (True, False):
                cfg = SelfJoinConfig(eps=eps, k=k, reorder=reorder,
                                     sortidu=True, shortc=False,
                                     tile_size=32, dim_block=16)
                r = self_join(d, cfg)        # warmup: XLA compiles here
                t = timeit(lambda: self_join(d, cfg))
                st = r.stats
                record(
                    f"fig6/{name}/k={k}/reorder={'on' if reorder else 'off'}",
                    t,
                    f"candidates={st.num_candidates};cells={st.num_nonempty_cells}",
                )


if __name__ == "__main__":
    run()
