"""Paper Fig. 12: communication cost of the distributed entity partitioning.

Measures the ring-pass (ppermute) wall time on 8 host devices in a
subprocess (BSP supersteps, paper Sec. 6.3) and reports the analytic wire
model: (|p|-1) * |D| elements total, |D| - |D|/|p| sent per node.

Also benchmarks the **device-fused indexed ring** (DESIGN.md #7 addendum)
against the host-driven BSP driver on the same 8-device mesh: the
``fused_ring`` rows record that the fused path compiles to ONE program
(traces=1) executed ONCE per join (executions_per_join=1, device
dispatches=1) while the host driver re-enters Python every round
(dispatches = its chunk-program launches), plus the warm wall time of both.

``--tiny`` (or BENCH_SMOKE=1) shrinks |D| so `make bench-smoke` can keep
this path compiling and running in CI-scale time.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit_bench_json, measure_fused_vs_host, record
from repro.core.distributed import ring_comm_elements

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, time
    sys.path.insert(0, sys.argv[1])
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import compat
    mesh = jax.make_mesh((8,), ("data",))
    n, dims = int(sys.argv[2]), int(sys.argv[3])
    x = jnp.zeros((n, dims), jnp.float32)
    x = jax.device_put(x, NamedSharding(mesh, P("data")))
    perm = [(j, (j + 1) % 8) for j in range(8)]

    def ring(v):
        def body(_, e):
            return jax.lax.ppermute(e, "data", perm)
        return jax.lax.fori_loop(0, 7, body, v)

    f = jax.jit(compat.shard_map(ring, mesh=mesh, in_specs=P("data"), out_specs=P("data")))
    f(x).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        f(x).block_until_ready()
    print("RING_US", (time.perf_counter() - t0) / 3 * 1e6)
    """
)

FULL_CELLS = [("Syn16D2M", 40_000, 16), ("SuSy", 40_000, 18)]
TINY_CELLS = [("Syn16D2M", 2_000, 16), ("SuSy", 2_000, 18)]

def run_fused(tiny: bool = False):
    """fused_ring rows: one-program-once contract + fused-vs-host wall time.

    The subprocess (``common.measure_fused_vs_host``) asserts count parity
    and the contract -- traces == 1, device dispatches == 1 per join.
    Emits ``BENCH_fused.json`` for the regression gate: the contracts pin
    the one-trace/one-dispatch discipline and the grid's filter ratio
    (candidates / n^2 -- deterministic for the fixed dataset, so index
    filtering power can never silently rot); the metrics gate the fused
    and host-driven warm wall times within the comparator's slack.
    """
    n, dims = (1_500, 16) if tiny else (12_000, 16)
    contracts: dict = {
        "count_parity": True,           # asserted inside the subprocess
        "pairs_parity": True,           # fused pair SET == host-driven SET
        "fused_traces": 1,
        "fused_dispatches_per_join": 1,
        "fused_pairs_traces": 1,
        "fused_pairs_dispatches_per_join": 1,
    }
    metrics: dict = {}
    info: dict = {"n": n, "dims": dims, "tiny": tiny}
    count_rows, pairs_rows = measure_fused_vs_host(n, dims, [8])
    for p, fused_us, host_us, host_disp, cand in count_rows:
        filter_ratio = cand / float(n * n)
        record(
            f"fused_ring/Syn{dims}D/p={p}", fused_us,
            f"traces=1;executions_per_join=1;device_dispatches=1;"
            f"host_dispatches={host_disp};"
            f"host_us={host_us:.1f};speedup_vs_host={host_us / fused_us:.2f};"
            f"filter_ratio={filter_ratio:.4f}",
        )
        contracts[f"filter_ratio_pct/p={p}"] = round(100.0 * filter_ratio, 2)
        metrics[f"fused_us/p={p}"] = fused_us
        metrics[f"host_us/p={p}"] = host_us
        info[f"host_dispatches/p={p}"] = host_disp
        info[f"speedup_vs_host/p={p}"] = round(host_us / fused_us, 2)
    for p, fp_us, hp_us, retries, npairs in pairs_rows:
        record(
            f"fused_pairs/Syn{dims}D/p={p}", fp_us,
            f"host_pairs_us={hp_us:.1f};"
            f"speedup_vs_host={hp_us / fp_us:.2f};"
            f"overflow_retries={retries};num_pairs={npairs}",
        )
        # the capacity/rank-window seeding must keep warm joins retry-free,
        # and the one-dispatch pairs ring must beat the |p|^2-block host
        # loop at p=8 (the acceptance row for DESIGN.md #7b)
        contracts[f"pair_overflow_retries/p={p}"] = retries
        contracts[f"fused_pairs_faster/p={p}"] = bool(fp_us < hp_us)
        metrics[f"fused_pairs_us/p={p}"] = fp_us
        metrics[f"host_pairs_us/p={p}"] = hp_us
        info[f"num_pairs/p={p}"] = npairs
        info[f"pairs_speedup_vs_host/p={p}"] = round(hp_us / fp_us, 2)
    emit_bench_json("fused", contracts=contracts, metrics=metrics, info=info)


def run(tiny: bool = False):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    for name, n, dims in (TINY_CELLS if tiny else FULL_CELLS):
        out = subprocess.run(
            [sys.executable, "-c", SCRIPT, src, str(n), str(dims)],
            capture_output=True, text=True, timeout=600,
            env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
        )
        if out.returncode != 0:
            raise RuntimeError(f"ring subprocess failed:\n{out.stderr[-2000:]}")
        us = float(out.stdout.split("RING_US")[-1].strip().split()[0])
        elems = ring_comm_elements(n, 8)
        record(
            f"fig12/{name}/p=8", us,
            f"total_elements={elems};bytes={elems * dims * 4};"
            f"per_node_sent={n - n // 8}",
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny", action="store_true",
        default=os.environ.get("BENCH_SMOKE") == "1",
        help="CI-scale configuration (also via BENCH_SMOKE=1)",
    )
    tiny = ap.parse_args().tiny
    run(tiny=tiny)
    run_fused(tiny=tiny)
