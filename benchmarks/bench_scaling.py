"""Paper Fig. 11: simulated multi-GPU scaling from measured batch times.

Round-robin assignment of the measured per-batch times (bench_partition_
balance writes them) to |p| workers; speedup vs |p|=1.  The paper reports
near-ideal scaling up to 128 -- entity partitioning makes batch costs
near-equal, so max-load ~ total/|p|.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import record
from repro.core import simulate_scaling
from benchmarks.bench_partition_balance import OUT as TIMES_FILE, run as _gen


def run():
    if not os.path.exists(TIMES_FILE):
        _gen()
    with open(TIMES_FILE) as f:
        data = json.load(f)
    for name, times in data.items():
        for mode in ("round_robin", "dynamic"):
            rows = simulate_scaling(
                np.asarray(times), [1, 2, 4, 8, 16, 32], assignment=mode
            )
            for p, t, speedup in rows:
                record(
                    f"fig11/{name}/{mode}/p={p}", t * 1e6,
                    f"speedup={speedup:.2f};ideal={p};efficiency={speedup / p:.3f}",
                )


if __name__ == "__main__":
    run()
