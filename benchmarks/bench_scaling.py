"""Paper Fig. 11: simulated multi-GPU scaling from measured batch times.

Round-robin assignment of the measured per-batch times (bench_partition_
balance writes them) to |p| workers; speedup vs |p|=1.  The paper reports
near-ideal scaling up to 128 -- entity partitioning makes batch costs
near-equal, so max-load ~ total/|p|.

``run_fused_vs_host`` adds *measured* rows for the distributed engine's two
drivers (DESIGN.md #7): host-driven BSP loop vs the device-fused ring, on
|p| in {1, 2, 4, 8} simulated devices -- the per-|p| dispatch overhead the
fusion removes grows with |p| (the host loop re-enters Python |p| times per
round x chunk programs; the fused ring is one dispatch regardless of |p|).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import measure_fused_vs_host, record
from repro.core import simulate_scaling
from benchmarks.bench_partition_balance import OUT as TIMES_FILE, run as _gen


def run_fused_vs_host(tiny: bool = False):
    n, dims = (1_500, 16) if tiny else (8_000, 16)
    for p, fused_us, host_us, host_disp, cand in measure_fused_vs_host(
        n, dims, [1, 2, 4, 8]
    ):
        record(
            f"fig11/fused_vs_host/p={p}", fused_us,
            f"host_us={host_us:.1f};"
            f"speedup_vs_host={host_us / fused_us:.2f};"
            f"fused_dispatches=1;host_dispatches={host_disp};"
            f"filter_ratio={cand / float(n * n):.4f}",
        )


def run():
    if not os.path.exists(TIMES_FILE):
        _gen()
    with open(TIMES_FILE) as f:
        data = json.load(f)
    for name, times in data.items():
        for mode in ("round_robin", "dynamic"):
            rows = simulate_scaling(
                np.asarray(times), [1, 2, 4, 8, 16, 32], assignment=mode
            )
            for p, t, speedup in rows:
                record(
                    f"fig11/{name}/{mode}/p={p}", t * 1e6,
                    f"speedup={speedup:.2f};ideal={p};efficiency={speedup / p:.3f}",
                )


if __name__ == "__main__":
    run()
    run_fused_vs_host(tiny=os.environ.get("BENCH_SMOKE") == "1")
