"""Paper Fig. 11: simulated multi-GPU scaling from measured batch times.

Round-robin assignment of the measured per-batch times (bench_partition_
balance writes them) to |p| workers; speedup vs |p|=1.  The paper reports
near-ideal scaling up to 128 -- entity partitioning makes batch costs
near-equal, so max-load ~ total/|p|.

``run_fused_vs_host`` adds *measured* rows for the distributed engine's two
drivers (DESIGN.md #7): host-driven BSP loop vs the device-fused ring, in
both counts and pairs mode, on |p| in {1, 2, 4, 8} simulated devices -- the
per-|p| dispatch overhead the fusion removes grows with |p| (the host loop
re-enters Python |p| times per round x chunk programs; the fused ring is
one dispatch regardless of |p|).  It emits ``BENCH_scaling.json`` for the
regression gate: host dispatch counts, zero-retry pairs joins, and the
LPT-vs-round-robin load balance of the deterministic cost model are
contracts; the per-|p| warm wall times are slack-gated metrics.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit_bench_json, measure_fused_vs_host, record
from repro.core import DistributedSelfJoinEngine, SelfJoinConfig, simulate_scaling
from repro.data import exponential_dataset
from benchmarks.bench_partition_balance import OUT as TIMES_FILE, run as _gen


def run_fused_vs_host(tiny: bool = False):
    n, dims = (1_500, 16) if tiny else (8_000, 16)
    contracts: dict = {
        "count_parity": True,
        "pairs_parity": True,
        "fused_dispatches_per_join": 1,
        "fused_pairs_dispatches_per_join": 1,
    }
    metrics: dict = {}
    info: dict = {"n": n, "dims": dims, "tiny": tiny}
    count_rows, pairs_rows = measure_fused_vs_host(n, dims, [1, 2, 4, 8])
    for p, fused_us, host_us, host_disp, cand in count_rows:
        record(
            f"fig11/fused_vs_host/p={p}", fused_us,
            f"host_us={host_us:.1f};"
            f"speedup_vs_host={host_us / fused_us:.2f};"
            f"fused_dispatches=1;host_dispatches={host_disp};"
            f"filter_ratio={cand / float(n * n):.4f}",
        )
        # chunk-program launch counts are deterministic for a fixed dataset:
        # drift means the schedule (not the machine) changed
        contracts[f"host_dispatches/p={p}"] = host_disp
        metrics[f"fused_us/p={p}"] = fused_us
        metrics[f"host_us/p={p}"] = host_us
    for p, fp_us, hp_us, retries, npairs in pairs_rows:
        record(
            f"fig11/fused_pairs_vs_host/p={p}", fp_us,
            f"host_pairs_us={hp_us:.1f};"
            f"speedup_vs_host={hp_us / fp_us:.2f};"
            f"overflow_retries={retries};num_pairs={npairs}",
        )
        contracts[f"pair_overflow_retries/p={p}"] = retries
        metrics[f"fused_pairs_us/p={p}"] = fp_us
        metrics[f"host_pairs_us/p={p}"] = hp_us
        info[f"num_pairs/p={p}"] = npairs

    # rr-vs-LPT: the deterministic cost model's worker loads (paper Sec. 6.2)
    # -- LPT over the estimated batch costs may never balance WORSE than
    # round-robin on the fixed benchmark dataset
    D = exponential_dataset(n, dims, seed=5)
    cfg = SelfJoinConfig(eps=0.06, k=4, tile_size=16)
    rr = DistributedSelfJoinEngine(D, cfg, num_workers=8).worker_loads()
    lpt = DistributedSelfJoinEngine(
        D, cfg, num_workers=8, assignment="dynamic"
    ).worker_loads()
    contracts["lpt_max_load_le_rr/p=8"] = bool(lpt.max() <= rr.max())
    info["rr_balance/p=8"] = round(float(rr.max() / rr.mean()), 3)
    info["lpt_balance/p=8"] = round(float(lpt.max() / lpt.mean()), 3)
    emit_bench_json("scaling", contracts=contracts, metrics=metrics, info=info)


def run():
    if not os.path.exists(TIMES_FILE):
        _gen()
    with open(TIMES_FILE) as f:
        data = json.load(f)
    for name, times in data.items():
        for mode in ("round_robin", "dynamic"):
            rows = simulate_scaling(
                np.asarray(times), [1, 2, 4, 8, 16, 32], assignment=mode
            )
            for p, t, speedup in rows:
                record(
                    f"fig11/{name}/{mode}/p={p}", t * 1e6,
                    f"speedup={speedup:.2f};ideal={p};efficiency={speedup / p:.3f}",
                )


if __name__ == "__main__":
    run()
    run_fused_vs_host(tiny=os.environ.get("BENCH_SMOKE") == "1")
