"""Device-resident engine vs the pre-refactor host-loop path.

The acceptance gate for the SelfJoinEngine refactor: on the same dataset and
config, ``SelfJoinEngine`` (jitted tiling + in-jit scatter / compaction) must
at least match ``self_join_hostloop`` (host make_tiles loop, ``np.add.at``,
``np.nonzero``) in wall time, for both counts and pairs mode.  Also reports
the engine's multi-eps sweep, which reuses one index and one set of compiled
chunk programs across eps values.
"""
from __future__ import annotations

from benchmarks.common import record, timeit
from repro.core import SelfJoinConfig, SelfJoinEngine
from repro.core.selfjoin import self_join_hostloop
from repro.data import exponential_dataset


def run(num_points: int = 6000, num_dims: int = 16, eps: float = 0.05):
    d = exponential_dataset(num_points, num_dims, seed=0)
    cfg = SelfJoinConfig(eps=eps, k=4, tile_size=32, dim_block=8)

    # counts mode -------------------------------------------------------
    host_us = timeit(lambda: self_join_hostloop(d, cfg), repeats=2)
    engine = SelfJoinEngine(d, cfg)   # index build + compile amortized...
    engine.count()                    # ...warm-up (compile) outside timing
    eng_us = timeit(lambda: engine.count(), repeats=2)
    cold_us = timeit(lambda: SelfJoinEngine(d, cfg).count())
    record("engine/counts/hostloop", host_us)
    record("engine/counts/engine_warm", eng_us,
           f"speedup={host_us / max(eng_us, 1e-9):.2f}x")
    record("engine/counts/engine_cold", cold_us,
           f"speedup={host_us / max(cold_us, 1e-9):.2f}x")

    # pairs mode --------------------------------------------------------
    host_us = timeit(lambda: self_join_hostloop(d, cfg, return_pairs=True),
                     repeats=2)
    engine.pairs()  # warm-up
    eng_us = timeit(lambda: engine.pairs(), repeats=2)
    record("engine/pairs/hostloop", host_us)
    record("engine/pairs/engine_warm", eng_us,
           f"speedup={host_us / max(eng_us, 1e-9):.2f}x")

    # multi-eps sweep: one index, zero recompiles between sweep points --
    sweep = [eps * s for s in (0.6, 0.8, 1.0)]
    engine.query(sweep)  # warm-up
    sweep_us = timeit(lambda: engine.query(sweep))
    fresh_us = timeit(
        lambda: [SelfJoinEngine(d, SelfJoinConfig(
            eps=e, k=4, tile_size=32, dim_block=8)).count() for e in sweep]
    )
    record("engine/sweep3/reused_engine", sweep_us,
           f"vs_fresh={fresh_us / max(sweep_us, 1e-9):.2f}x")
    record("engine/sweep3/fresh_engines", fresh_us)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
