"""Paper Table 3: GPU-Join (all optimizations) speedup over the EGO-class
baseline, at the smallest and largest eps per dataset."""
from __future__ import annotations

from benchmarks.common import record, timeit
from repro.core import SelfJoinConfig, select_k, self_join
from repro.core.ego import ego_join_counts
from repro.data import paper_dataset

CASES = [
    ("ColorHist", 0.05, [0.05, 0.5]),
    ("LayoutHist", 0.05, [0.05, 0.5]),
    ("CoocTexture", 0.05, [0.05, 0.2]),
    ("SuSy", 0.001, [0.01, 0.02]),
    ("Songs", 0.006, [0.005, 0.01]),
    ("Syn16D2M", 0.0015, [0.03, 0.05]),
    ("Syn32D2M", 0.0015, [0.08, 0.1]),
    ("Syn64D2M", 0.0015, [0.16, 0.18]),
]


def run():
    for name, scale, eps_pair in CASES:
        d = paper_dataset(name, scale)
        for eps in eps_pair:
            k = select_k(d, eps, ks=[2, 3, 4, 6])
            cfg = SelfJoinConfig(eps=eps, k=k, reorder=True, sortidu=True,
                                 shortc=False, tile_size=32,
                                 dim_block=16)
            self_join(d, cfg)                # warmup: XLA compiles here
            t_join = timeit(lambda: self_join(d, cfg))
            t_ego = timeit(lambda: ego_join_counts(d, eps))
            record(
                f"table3/{name}/eps={eps}", t_join,
                f"ego_us={t_ego:.0f};speedup={t_ego / max(t_join, 1):.2f}x",
            )


if __name__ == "__main__":
    run()
